//! Event-driven edge-cloud simulator (§5.2).
//!
//! The paper: "an event-driven simulation architecture ... fully executes
//! the request scheduling process but bypasses the actual execution of
//! packet transmission and model computations.  Transmission latency is
//! simulated based on service-specific data volumes and network bandwidth,
//! while computational latency is derived from lookup tables".  Identical
//! here: virtual time, a binary-heap event queue, the §3.2 handler making
//! every routing decision against *synced (stale)* state, deployments as
//! batch-amortized processors with rates from [`crate::profile`].
//!
//! Policies (EPARA + the six baselines) parameterize the same engine via
//! [`PolicyConfig`] so comparisons isolate scheduling, not bookkeeping.

use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::allocator::{Allocation, Allocator, Overrides};
use crate::cluster::{EdgeCloud, GpuSpec};
use crate::core::{
    DeviceId, Outcome, Request, Sensitivity, ServerId, ServiceId, TaskCategory,
};
use crate::handler::{
    decide_with, Decision, HandlerConfig, LocalCapacity, OffloadScratch, StateView,
};
use crate::metrics::Metrics;
use crate::modelcache::{CacheConfig, CacheFabric, CacheKind};
use crate::placement::{sssp, FluidEval, PhiEval, PlacementItem, EPSILON_SERVER};
use crate::predict::{PredictConfig, RateForecaster};
use crate::profile::ProfileTable;
use crate::server::resilience::{self, Breaker, ResilienceConfig, RetryBudget};
use crate::sync::{SyncConfig, SyncNet};
use crate::util::grid::{ServiceIndex, StateGrid};
use crate::util::heap::{Keyed, MinTimeKey};
use crate::util::Rng;

pub mod policy;
pub mod runcfg;

pub use runcfg::RunConfig;
pub use policy::{OffloadMode, PlacementMode, PolicyConfig};

// --------------------------------------------------------------------------
// events
// --------------------------------------------------------------------------

/// High bit of `Finish::dep` marks a device-backed deployment (replaces the
/// old `usize::MAX - dep` encoding and keeps the payload at 4 bytes).
const DEVICE_FLAG: u32 = 1 << 31;

/// Event payloads are index-sized: requests live in a slab owned by the
/// simulator and events carry `u32` slab indices, so pushing an event never
/// allocates (the old encoding boxed a `Request` clone per arrival/hop).
#[derive(Debug)]
enum EventKind {
    /// Request (slab index) reaches a server (user arrival or offload
    /// landing).
    Arrive { req: u32, at: ServerId },
    /// A deployment finishes its current job (`dep` may carry
    /// [`DEVICE_FLAG`]).
    Finish { server: ServerId, dep: u32 },
    /// Periodic sync round completes.
    SyncRound,
    /// Periodic service re-placement (§3.4 coarse granularity).
    PlacementRound,
    /// Scripted scenario action (index into the fault script).
    Fault { idx: u32 },
    /// Periodic metrics sample (scenario phase/recovery accounting).
    Sample,
}

/// Min-heap ordering (time, then seq for determinism) comes from the shared
/// `util::heap` key types — see `MinTimeKey`.
type Event = Keyed<MinTimeKey, EventKind>;

// --------------------------------------------------------------------------
// scripted faults (scenario engine)
// --------------------------------------------------------------------------

/// One scripted chaos action, applied at a virtual instant of the run
/// (the scenario engine's injection surface; §5.3.3 generalized from the
/// original one-shot `fail_gpu_containment`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Whole-server GPU outage: live deployments retire, queued requests
    /// drain as `ResourceInsufficient`, GPUs flag failed, and the sync
    /// ring marks the server down (detected loss, §5.3.3).
    FailServer(ServerId),
    /// Bring a failed server back: GPUs heal, the ring repairs, and
    /// service is restored — by an immediate re-placement round when
    /// periodic re-placement is on, else by reinstating the failed
    /// roster (both pay the Fig. 3f model-load delay).
    RecoverServer(ServerId),
    /// Edge device deregisters (§3.2 churn): its deployment retires.
    DeviceLeave(DeviceId),
    /// Edge device (re)registers and contributes a deployment again.
    DeviceJoin(DeviceId),
    /// Multiply the batch-window time of every live deployment on the
    /// server (degraded clocks / thermal throttling); factor < 1 undoes
    /// an earlier skew.
    LatencySkew { server: ServerId, factor: f64 },
    /// No state change: force a metrics sample at this instant (phase
    /// boundaries for trace-level events like surges).
    Checkpoint,
    /// Executor fault injection: every execution start fails with this
    /// probability (seeded, drawn from an independent fault stream).
    /// `rate` 0 clears an earlier window.
    ExecFaultRate { rate: f64 },
    /// Multiply execution time of every request started from now on
    /// (backend brown-out); `factor` 1 clears an earlier slowdown.
    ExecSlowdown { factor: f64 },
}

/// Cumulative outcome counters sampled at a virtual instant.  Deltas
/// between samples give per-phase goodput and SLO-violation rates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimSample {
    pub at_ms: f64,
    pub offered: u64,
    pub satisfied: f64,
    pub completed: u64,
    pub timeout: u64,
    pub offload_exceeded: u64,
    pub resource_insufficient: u64,
    /// Cumulative weight-cache admissions (all zero when the cache is off).
    pub cache_hits: u64,
    pub cache_partial: u64,
    pub cache_misses: u64,
    pub cache_bytes_loaded_mb: f64,
    pub cache_bytes_saved_mb: f64,
    /// Cumulative resilience counters (all zero while resilience is off).
    pub retries: u64,
    pub deadline_expired: u64,
    pub breaker_trips: u64,
    pub breaker_short_circuits: u64,
    /// Cumulative forecast-triggered early placement rounds (zero while
    /// prediction is off).
    pub pred_early_rounds: u64,
}

/// What a failed server hosted, for offline-mode recovery re-install.
#[derive(Clone, Copy, Debug)]
struct StashedDep {
    service: ServiceId,
    cross: bool,
}

// --------------------------------------------------------------------------
// deployments: batch-amortized processors
// --------------------------------------------------------------------------

/// One placed deployment of a service on a server (one MPS slice, all DP
/// groups), modeled as a **batch-window processor**:
///
/// * every `window_ms` the deployment completes one batch of `bs` items;
/// * a request owns `mf` slots of each batch (Eq. 5), so it advances
///   `mf` items per window and `cap = ⌊bs/mf⌋` requests ride concurrently;
/// * a request of F frames therefore takes ⌈F/mf⌉ windows — which is
///   exactly why frequency tasks need MF (mf=1 means a 120-frame stream
///   needs 120 windows and misses its fps SLO even at low utilization,
///   the §2.3 motivation).
#[derive(Debug)]
struct Deployment {
    service: ServiceId,
    /// Model still loading until this time (Fig. 3f: placement takes
    /// >= 2.5x a single task; fresh deployments are not yet servable).
    available_at_ms: f64,
    /// Retired by a re-placement round: drains its queue, accepts no more.
    retired: bool,
    /// One batch window (ms): profiled latency at (bs, mp, mt=1).
    window_ms: f64,
    /// Multi-frame slots this service's requests occupy per batch.
    mf: u32,
    /// Concurrent requests per Eq. (5): max(1, bs/mf).
    cap: u32,
    /// Requests/s this slice sustains (for the synced theoretical p̂).
    req_rate: f64,
    /// Cross-server (ε) deployment: per-window hop overhead.
    cross_server: bool,
    /// Requests currently executing.
    in_flight: u32,
    /// Sum of queued work (ms) — the §3.2 queued-compute signal.
    queued_ms: f64,
    /// Waiting requests as slab indices (the slab owns the `Request`s).
    queue: VecDeque<u32>,
}

impl Deployment {
    /// Service time of one request of `frames` items (ms).
    fn service_ms(&self, frames: u32) -> f64 {
        let cross = if self.cross_server { 1.25 } else { 1.0 };
        let windows = (frames as f64 / self.mf as f64).ceil().max(1.0);
        windows * self.window_ms * cross
    }

    /// Expected wait before a new request starts (ms), relative to `now`.
    fn wait_from(&self, now_ms: f64) -> f64 {
        let loading = (self.available_at_ms - now_ms).max(0.0);
        let queue = if self.in_flight < self.cap {
            0.0
        } else {
            self.queued_ms / self.cap as f64
        };
        loading + queue
    }
}

/// Per-server live state.
#[derive(Debug, Default)]
struct SimServer {
    deployments: Vec<Deployment>,
    /// Device-backed deployments (single-GPU services on registered
    /// device GPUs, §3.2 "edge device participation").
    device_deps: Vec<(DeviceId, Deployment)>,
}

/// Snapshot of one (server, service): what the sync protocol distributed.
#[derive(Clone, Copy, Debug, Default)]
struct SyncedEntry {
    theoretical: f64,
    actual: f64,
    queued_ms: f64,
}

// --------------------------------------------------------------------------
// the state view handed to the handler
// --------------------------------------------------------------------------

struct SimView<'a> {
    snap: &'a StateGrid<SyncedEntry>,
    svc_index: &'a ServiceIndex,
    servers: &'a [SimServer],
    sync: &'a SyncNet,
    table: &'a ProfileTable,
    now_ms: f64,
    n: usize,
    /// Policy knob: offloading disabled (AlpaServe) etc.
    allow_cross_server: bool,
    allow_device: bool,
}

impl SimView<'_> {
    #[inline]
    fn entry(&self, s: ServerId, l: ServiceId) -> SyncedEntry {
        match self.svc_index.get(l) {
            Some(li) => *self.snap.get(s.0 as usize, li),
            None => SyncedEntry::default(),
        }
    }
}

impl StateView for SimView<'_> {
    fn n_servers(&self) -> usize {
        self.n
    }

    fn local_capacity(&self, server: ServerId, service: ServiceId) -> LocalCapacity {
        let srv = &self.servers[server.0 as usize];
        let spec = self.table.spec(service);
        let typical = spec.frames_per_request.max(1);
        // Deadline a typical request must meet end-to-end: the latency
        // SLO for latency tasks; the rate-implied session budget for
        // frequency tasks (F frames at >= R fps means finishing within
        // F/R seconds — §3.3's satisfaction criterion).
        // Frequency sessions earn fractional credit below target rate
        // (§3.3), so admission accepts anything that can still earn at
        // least ~25% credit rather than dropping it outright.
        let budget = match spec.slo.min_rate {
            None => spec.slo.latency_ms,
            Some(rate) => typical as f64 / rate * 1000.0 * 4.0,
        };
        let now = self.now_ms;
        let fits = |d: &Deployment| !d.retired
            && d.wait_from(now) + d.service_ms(typical) <= budget;

        // plain local deployments first (§3.2 priority 1)
        for d in &srv.deployments {
            if d.service == service && !d.cross_server && fits(d) {
                return LocalCapacity::Ready;
            }
        }
        // cross-server parallel deployments (priority 2)
        if self.allow_cross_server {
            for d in &srv.deployments {
                if d.service == service && d.cross_server && fits(d) {
                    return LocalCapacity::CrossServerParallel;
                }
            }
        }
        // registered device GPUs (priority 3)
        if self.allow_device {
            for (dev, d) in &srv.device_deps {
                if d.service == service && fits(d) {
                    return LocalCapacity::Device(*dev);
                }
            }
        }
        // saturated or absent: fall through to offloading (§2.2)
        LocalCapacity::None
    }

    fn theoretical_goodput(&self, server: ServerId, service: ServiceId) -> f64 {
        if self.sync.is_down(server) {
            return 0.0;
        }
        self.entry(server, service).theoretical
    }

    fn actual_goodput(&self, server: ServerId, service: ServiceId) -> f64 {
        let e = self.entry(server, service);
        // silent sync errors distort the view (§5.3.3 / Fig. 19a)
        e.actual * self.sync.state_distortion(server)
    }

    fn queued_ms(&self, server: ServerId, service: ServiceId) -> f64 {
        self.entry(server, service).queued_ms
    }

    fn sync_delay_ms(&self, server: ServerId) -> f64 {
        self.sync.staleness_ms(server, self.now_ms)
    }

    fn slo_ms(&self, service: ServiceId) -> f64 {
        self.table.spec(service).slo.latency_ms
    }
}

// --------------------------------------------------------------------------
// simulator
// --------------------------------------------------------------------------

/// Simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub seed: u64,
    pub handler: HandlerConfig,
    pub sync: SyncConfig,
    pub policy: PolicyConfig,
    /// Virtual horizon (ms); requests beyond it are not injected.
    pub duration_ms: f64,
    /// Periodic re-placement interval (§3.4 coarse granularity); None =
    /// place once from the whole trace (the paper's offline mode).
    pub replacement_interval_ms: Option<f64>,
    /// Per-server weight cache (modelcache subsystem).  The default
    /// capacity of 0 disables it: deployment spawns pay the flat Fig. 3f
    /// `model_load_ms` exactly as before, bit-for-bit.
    pub cache: CacheConfig,
    /// Request-lifecycle resilience (deadline budgets, bounded retries,
    /// per-service circuit breakers) — same state machines the gateway
    /// runs, driven by virtual time.  Disabled by default: the execution
    /// path is reproduced bit-for-bit.
    pub resilience: ResilienceConfig,
    /// Online prediction (DESIGN.md §Prediction): per-category Holt
    /// arrival forecasters that pull a placement round forward when a
    /// category's projected demand crosses provisioned capacity before
    /// the next scheduled round.  Requires `replacement_interval_ms`.
    /// Disabled by default: the event stream is reproduced bit-for-bit.
    pub predict: PredictConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            seed: 7,
            handler: HandlerConfig::default(),
            sync: SyncConfig::default(),
            policy: PolicyConfig::epara(),
            duration_ms: 60_000.0,
            replacement_interval_ms: None,
            cache: CacheConfig::default(),
            resilience: ResilienceConfig::default(),
            predict: PredictConfig::default(),
        }
    }
}

/// Virtual-time resilience state: the gateway's retry-budget and breaker
/// state machines (shared code, `now_ms` = virtual time).  Backoff
/// jitter draws from the simulator's independent fault stream, so a
/// resilience-off run never touches it.
struct SimResil {
    budget: RetryBudget,
    /// Breakers keyed per (server, service) — the sim's analogue of the
    /// gateway's per-(shard, service) keying.
    breakers: HashMap<(u32, u32), Breaker>,
}

/// Salt for the independent fault/backoff RNG stream.  Constructed from
/// the seed directly (NOT forked from the trace rng — forking advances
/// the parent and would shift every downstream handler draw).
const FAULT_RNG_SALT: u64 = 0xFA17_5EED_0BAD_C0DE;

/// Virtual-time prediction state (DESIGN.md §Prediction): per-category
/// Holt arrival forecasters plus the demand the current placement was
/// sized for, driving forecast-triggered early placement rounds.
struct SimPredict {
    cfg: PredictConfig,
    /// One forecaster per task category (index = `sim_cat_index`).
    forecasters: [RateForecaster; 4],
    /// Arrival rate (req/s) per category over the window the last
    /// placement round consumed — what the current placement is
    /// provisioned for.  0 = no baseline yet (never triggers).
    provisioned: [f64; 4],
    /// Earliest virtual time the next proactive round may fire.
    next_allowed_ms: f64,
    /// When the next *scheduled* round fires — the forecast horizon.
    next_sched_round_ms: f64,
    /// Category index per service grid column (aligned with svc_index).
    svc_cat: Vec<u8>,
}

/// Category → forecaster slot under the reference P100 VRAM (the same
/// classification the gateway's admission lanes use).
fn sim_cat_index(cat: TaskCategory) -> usize {
    match cat {
        TaskCategory::LatencySingle => 0,
        TaskCategory::LatencyMulti => 1,
        TaskCategory::FrequencySingle => 2,
        TaskCategory::FrequencyMulti => 3,
    }
}

/// The simulator.
///
/// §Perf (DESIGN.md): all per-`(server, service)` state lives in dense
/// [`StateGrid`] arenas addressed through a [`ServiceIndex`] built once at
/// construction; the event loop is allocation-free in steady state —
/// requests live in a slab, events carry `u32` indices, and the per-window
/// accumulators are reused scratch vectors.
pub struct Simulator<'a> {
    pub table: &'a ProfileTable,
    pub cloud: EdgeCloud,
    pub cfg: SimConfig,
    pub allocs: HashMap<ServiceId, Allocation>,
    pub placement: Vec<PlacementItem>,
    servers: Vec<SimServer>,
    /// Dense ServiceId → grid-column map over the trace's service universe.
    svc_index: ServiceIndex,
    /// Synced snapshot per (server, service).
    snap: StateGrid<SyncedEntry>,
    sync: SyncNet,
    events: BinaryHeap<Event>,
    seq: u64,
    pub metrics: Metrics,
    rng: Rng,
    /// Completed credit per (server, service) since last sync (actual p).
    window_done: StateGrid<f64>,
    last_sync_ms: f64,
    /// When the current placement was applied (0 = offline pre-placement).
    placement_applied_at_ms: f64,
    /// All requests of the run; events and queues refer to slab indices.
    slab: Vec<Request>,
    /// First-hop arrivals (slab indices) since the last placement round
    /// (the next round's R^T).
    window_requests: Vec<u32>,
    /// Reusable per-service accumulators for snapshot/sync rounds.
    scratch_theo: Vec<f64>,
    scratch_queued: Vec<f64>,
    scratch_seen: Vec<bool>,
    /// Reusable Eq. (1) weight buffer for the handler.
    offload_scratch: OffloadScratch,
    /// Scripted scenario actions, sorted by time at `run`.
    script: Vec<(f64, FaultAction)>,
    /// Cumulative counter samples (per scripted action + periodic ticks).
    samples: Vec<SimSample>,
    /// Periodic sampling cadence (None = only scripted-action samples).
    sample_interval_ms: Option<f64>,
    /// Per-server roster stashed at failure for offline-mode recovery.
    stash: Vec<Vec<StashedDep>>,
    /// Current latency-skew factor per server (1.0 = none); deployments
    /// installed while a skew is active inherit it, so a later revert
    /// (×1/factor) is correct for them too.
    server_skew: Vec<f64>,
    /// When the last placement round consumed its window (demand span).
    last_round_ms: f64,
    /// Per-server weight caches; `None` when `cfg.cache` is disabled —
    /// the legacy flat-load path, untouched bit-for-bit.
    cache: Option<CacheFabric>,
    /// Independent RNG stream for fault draws and retry backoff jitter.
    /// Never advanced unless an `ExecFaultRate` window is active, so the
    /// trace rng — and every fault-free run — is unaffected.
    fault_rng: Rng,
    /// Current executor fault probability (0 = off).
    exec_fault_rate: f64,
    /// Current execution-time multiplier (1 = off).
    exec_slow_factor: f64,
    /// Resilience state; `None` when `cfg.resilience` is disabled —
    /// the legacy execution path, untouched bit-for-bit.
    resil: Option<SimResil>,
    /// Prediction state; `None` when `cfg.predict` is disabled (or no
    /// periodic re-placement runs) — the legacy round cadence, untouched
    /// bit-for-bit.
    predict: Option<SimPredict>,
}

impl<'a> Simulator<'a> {
    /// Build: allocate operators per policy, place services, materialize
    /// deployments.
    pub fn new(
        table: &'a ProfileTable,
        cloud: EdgeCloud,
        requests: &[Request],
        cfg: SimConfig,
    ) -> Self {
        let services: Vec<ServiceId> = {
            let mut s: Vec<ServiceId> =
                requests.iter().map(|r| r.service).collect();
            s.sort();
            s.dedup();
            s
        };
        let allocator = Allocator::new(table, GpuSpec::P100);
        let allocs: HashMap<ServiceId, Allocation> = services
            .iter()
            .map(|&id| {
                let mut al = allocator.allocate(id, Overrides::default());
                cfg.policy.adjust_allocation(&mut al);
                (id, al)
            })
            .collect();

        // ---- placement ---------------------------------------------------
        let placement = match cfg.policy.placement {
            PlacementMode::Sssp => {
                let mut eval = FluidEval::from_requests(
                    table, &allocs, &cloud, requests, cfg.duration_ms);
                sssp(&[], &services, cloud.n_servers(), &mut eval);
                // VRAM-fill pass: keep packing replicas of demanded
                // services into leftover slots/VRAM (zero marginal fluid
                // gain, real burst headroom) — this is how the paper's
                // testbed reaches 98%+ VRAM residency (Fig. 13).
                let mut by_demand: Vec<ServiceId> = services.clone();
                by_demand.sort_by(|a, b| {
                    eval.demand_of(*b).partial_cmp(&eval.demand_of(*a)).unwrap()
                });
                'fill: for _round in 0..64 {
                    let mut placed = false;
                    for &svc in &by_demand {
                        if eval.demand_of(svc) <= 0.0 {
                            continue;
                        }
                        for n in 0..cloud.n_servers() {
                            let item = PlacementItem {
                                service: svc,
                                server: ServerId(n as u32),
                            };
                            if eval.feasible(item) {
                                eval.push(item);
                                placed = true;
                                break;
                            }
                        }
                    }
                    if !placed {
                        break 'fill;
                    }
                }
                eval.placement().to_vec()
            }
            PlacementMode::Cache(policy) => {
                let mut eval = FluidEval::from_requests(
                    table, &allocs, &cloud, requests, cfg.duration_ms);
                crate::placement::cache_baselines::place(
                    policy, requests, cloud.n_servers(), &mut eval)
            }
            PlacementMode::LocalOnly => {
                // AlpaServe-style: place by local demand only, no ε stage
                let mut eval = FluidEval::from_requests(
                    table, &allocs, &cloud, requests, cfg.duration_ms);
                let all: Vec<PlacementItem> = services
                    .iter()
                    .flat_map(|&l| {
                        (0..cloud.n_servers()).map(move |n| PlacementItem {
                            service: l,
                            server: ServerId(n as u32),
                        })
                    })
                    .collect();
                crate::placement::spf_lazy(&all, &mut eval);
                eval.placement().to_vec()
            }
        };

        let n = cloud.n_servers();
        // Service universe of the run: every service in the trace (allocs
        // and placement are derived from the same set).  Grid columns and
        // the FluidEval index share this ordering.
        let svc_index = ServiceIndex::new(services.iter().copied());
        let ns = svc_index.len();
        let mut sim = Simulator {
            table,
            cloud,
            servers: (0..n).map(|_| SimServer::default()).collect(),
            svc_index,
            snap: StateGrid::new(n, ns),
            sync: SyncNet::new(n, cfg.sync),
            events: BinaryHeap::new(),
            seq: 0,
            metrics: Metrics::new(),
            rng: Rng::new(cfg.seed),
            window_done: StateGrid::new(n, ns),
            last_sync_ms: 0.0,
            placement_applied_at_ms: 0.0,
            slab: Vec::new(),
            window_requests: Vec::new(),
            scratch_theo: vec![0.0; ns],
            scratch_queued: vec![0.0; ns],
            scratch_seen: vec![false; ns],
            offload_scratch: OffloadScratch::new(),
            script: Vec::new(),
            samples: Vec::new(),
            sample_interval_ms: None,
            stash: (0..n).map(|_| Vec::new()).collect(),
            server_skew: vec![1.0; n],
            last_round_ms: 0.0,
            cache: cfg
                .cache
                .enabled()
                .then(|| CacheFabric::new(table, n, cfg.cache.capacity_mb)),
            fault_rng: Rng::new(cfg.seed ^ FAULT_RNG_SALT),
            exec_fault_rate: 0.0,
            exec_slow_factor: 1.0,
            resil: cfg.resilience.enabled.then(|| SimResil {
                budget: RetryBudget::new(cfg.resilience.retry_budget, cfg.resilience.retry_burst),
                breakers: HashMap::new(),
            }),
            predict: None,
            allocs,
            placement: placement.clone(),
            cfg,
        };
        // Prediction only matters when periodic re-placement runs (the
        // trigger pulls a *scheduled* round forward); built after the
        // literal because the service→category map needs svc_index.
        if sim.cfg.predict.enabled {
            if let Some(interval) = sim.cfg.replacement_interval_ms {
                let pcfg = sim.cfg.predict;
                let svc_cat: Vec<u8> = (0..sim.svc_index.len())
                    .map(|col| {
                        let id = sim.svc_index.id_at(col);
                        let cat = sim
                            .table
                            .spec(id)
                            .category(crate::profile::zoo::P100_VRAM_MB);
                        sim_cat_index(cat) as u8
                    })
                    .collect();
                sim.predict = Some(SimPredict {
                    cfg: pcfg,
                    forecasters: [RateForecaster::new(&pcfg); 4],
                    provisioned: [0.0; 4],
                    next_allowed_ms: 0.0,
                    next_sched_round_ms: interval,
                    svc_cat,
                });
            }
        }
        sim.metrics.cache_enabled = sim.cache.is_some();
        sim.metrics.resilience_enabled = sim.cfg.resilience.enabled;
        sim.metrics.predict_enabled = sim.predict.is_some();
        sim.materialize_placement(&placement);
        sim.install_devices();
        sim.prime_snapshot();
        sim
    }

    /// Turn placement items into live deployments.
    fn materialize_placement(&mut self, placement: &[PlacementItem]) {
        // ε deployments land round-robin across live servers
        let mut eps_cursor = 0usize;
        for item in placement {
            // one placement = one MPS slice (mt=1); MT packing emerges
            // from multiple placements landing on the same server
            let cross = item.server == EPSILON_SERVER;
            let server = if cross {
                self.next_eps_server(&mut eps_cursor)
            } else {
                item.server
            };
            self.spawn_deployment(server, item.service, cross);
        }
    }

    /// Round-robin ε-deployment target, skipping servers detected down
    /// (§5.3.3 exclusion) — identical to plain round-robin while the
    /// cloud is healthy, so historical runs are unaffected.
    fn next_eps_server(&self, cursor: &mut usize) -> ServerId {
        let n = self.servers.len();
        for _ in 0..n {
            let s = ServerId((*cursor % n) as u32);
            *cursor += 1;
            if !self.sync.is_down(s) {
                return s;
            }
        }
        // every server down: degenerate, keep the last candidate
        ServerId(((*cursor - 1) % n) as u32)
    }

    /// Create one live deployment of `service` on `server` — shared by
    /// initial materialization, placement rounds, and fault recovery.
    /// Fresh deployments installed after t=0 pay the Fig. 3f model-load
    /// delay (`placement_applied_at_ms` is the installation instant).
    fn spawn_deployment(&mut self, server: ServerId, service: ServiceId, cross: bool) {
        let al = &self.allocs[&service];
        let window = self.table.latency_ms(service, al.ops.bs, al.ops.mp, 1)
            / al.ops.dp.max(1) as f64; // DP groups halve the window share
        let mf = al.ops.mf.max(1);
        let cap = al.ops.inter_request_count().max(1);
        let req_rate = self.table.request_rate(service, al.ops.bs, al.ops.mp, 1)
            * al.ops.dp as f64;
        let available_at_ms =
            self.placement_applied_at_ms + self.spawn_load_ms(server, service);
        // installed on a throttled server: inherit its current skew (1.0
        // while healthy, so the common path is bit-identical)
        let skew = self.server_skew[server.0 as usize];
        self.servers[server.0 as usize].deployments.push(Deployment {
            service,
            available_at_ms,
            retired: false,
            window_ms: (window.max(1e-3) * skew).max(1e-3),
            mf,
            cap,
            req_rate: req_rate / skew,
            cross_server: cross,
            in_flight: 0,
            queued_ms: 0.0,
            queue: VecDeque::new(),
        });
    }

    /// Model-load delay one spawn pays (Fig. 3f), cache-adjusted when the
    /// weight cache is on: only bytes not already resident on the server
    /// cost time, so a family sibling pays its delta and a recently
    /// retired model re-installs for free.  Initial pre-placement happens
    /// before t=0 (§2.3): zero delay either way, but it still pre-warms
    /// the cache so the horizon starts from a realistic resident set.
    fn spawn_load_ms(&mut self, server: ServerId, service: ServiceId) -> f64 {
        let now = self.placement_applied_at_ms;
        let base = self.table.spec(service).model_load_ms;
        let Some(fabric) = self.cache.as_mut() else {
            if now > 0.0 {
                self.metrics.model_load_ms_total += base;
                return base;
            }
            return 0.0;
        };
        let out = fabric.admit(server, service, now);
        if now <= 0.0 {
            return 0.0; // pre-warm only
        }
        match out.kind {
            CacheKind::Hit => self.metrics.cache_hits += 1,
            CacheKind::Partial => self.metrics.cache_partial += 1,
            CacheKind::Miss => self.metrics.cache_misses += 1,
        }
        self.metrics.cache_bytes_loaded_mb += out.bytes_loaded_mb;
        self.metrics.cache_bytes_saved_mb += out.bytes_saved_mb;
        let load_ms = base * out.load_frac;
        self.metrics.model_load_ms_total += load_ms;
        load_ms
    }

    /// Register device GPUs as single-GPU deployments at their home server.
    fn install_devices(&mut self) {
        if !self.cfg.policy.allow_device {
            return;
        }
        let devices: Vec<(DeviceId, ServerId, GpuSpec)> = self
            .cloud
            .devices
            .iter()
            .filter(|d| d.registered)
            .filter_map(|d| d.kind.gpu().map(|g| (d.id, d.home, g)))
            .collect();
        for (dev, home, gpu) in devices {
            self.install_device(dev, home, gpu);
        }
    }

    /// Register one device GPU as a single-GPU deployment at its home
    /// server (shared by construction, device churn, and server recovery).
    /// No-op when the device already has a live deployment there.
    fn install_device(&mut self, dev: DeviceId, home: ServerId, gpu: GpuSpec) {
        if self.servers[home.0 as usize]
            .device_deps
            .iter()
            .any(|(d, dep)| *d == dev && !dep.retired)
        {
            return;
        }
        // pick the lightest single-GPU service with demand
        let candidate = self
            .allocs
            .iter()
            .filter(|(id, _)| {
                let spec = self.table.spec(**id);
                spec.fits_single_gpu(gpu.vram_mb)
                    && spec.vram_mb <= gpu.vram_mb
            })
            .min_by(|a, b| {
                let va = self.table.spec(*a.0).vram_mb;
                let vb = self.table.spec(*b.0).vram_mb;
                // tie-break on id: `allocs` iterates in hash order, and
                // equal-VRAM ties must not depend on it
                va.partial_cmp(&vb).unwrap().then(a.0.cmp(b.0))
            });
        if let Some((&svc, al)) = candidate {
            let slow = 1.0 / gpu.compute.max(1e-3);
            let link = self.cloud.device_link(dev);
            // device window: compute slowdown + request shipping cost
            let window = self.table.latency_ms(svc, al.ops.bs, al.ops.mp, 1)
                * slow
                + link.transfer_ms(self.table.spec(svc).payload_kb);
            let req_rate = self.table.request_rate(svc, al.ops.bs, al.ops.mp, 1)
                / slow;
            let mf = al.ops.mf.max(1);
            let cap = al.ops.inter_request_count().max(1);
            // device lanes ride the home server's coordination path:
            // inherit its current skew (1.0 while healthy)
            let skew = self.server_skew[home.0 as usize];
            self.servers[home.0 as usize].device_deps.push((
                dev,
                Deployment {
                    service: svc,
                    available_at_ms: 0.0,
                    retired: false,
                    window_ms: (window.max(1e-3) * skew).max(1e-3),
                    mf,
                    cap,
                    req_rate: req_rate / skew,
                    cross_server: false,
                    in_flight: 0,
                    queued_ms: 0.0,
                    queue: VecDeque::new(),
                },
            ));
        }
    }

    /// Fill the synced snapshot with theoretical rates (placement known
    /// cloud-wide after each placement round).
    fn prime_snapshot(&mut self) {
        let ns = self.svc_index.len();
        for si in 0..self.servers.len() {
            self.scratch_theo[..ns].fill(0.0);
            self.scratch_seen[..ns].fill(false);
            for d in &self.servers[si].deployments {
                if !d.retired {
                    if let Some(li) = self.svc_index.get(d.service) {
                        self.scratch_theo[li] += d.req_rate;
                        self.scratch_seen[li] = true;
                    }
                }
            }
            for li in 0..ns {
                if self.scratch_seen[li] {
                    *self.snap.get_mut(si, li) = SyncedEntry {
                        theoretical: self.scratch_theo[li],
                        actual: 0.0,
                        queued_ms: 0.0,
                    };
                }
            }
        }
    }

    fn push_event(&mut self, at_ms: f64, kind: EventKind) {
        self.seq += 1;
        self.events
            .push(Keyed::new(MinTimeKey { at_ms, seq: self.seq }, kind));
    }

    /// Run the trace to completion; returns final metrics.
    ///
    /// `requests` should be the same trace handed to [`Simulator::new`]
    /// (placement demand and the service index are derived from it); the
    /// vector is moved into the simulator's request slab unchanged.
    pub fn run(&mut self, requests: Vec<Request>) -> &mut Metrics {
        self.slab = requests;
        for i in 0..self.slab.len() {
            let (arrival, origin) = {
                let r = &self.slab[i];
                (r.arrival_ms, r.origin)
            };
            if arrival <= self.cfg.duration_ms {
                self.push_event(
                    arrival,
                    EventKind::Arrive { req: i as u32, at: origin },
                );
            }
        }
        let interval = self.cfg.sync.interval_ms;
        self.push_event(interval, EventKind::SyncRound);
        if let Some(p) = self.cfg.replacement_interval_ms {
            self.push_event(p, EventKind::PlacementRound);
        }
        // scripted scenario actions interleave deterministically with the
        // trace: stable sort keeps same-instant actions in schedule order
        self.script.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for i in 0..self.script.len() {
            let at = self.script[i].0;
            self.push_event(at, EventKind::Fault { idx: i as u32 });
        }
        if self.sample_interval_ms.is_some() || !self.script.is_empty() {
            // initial row: phase accounting starts from zeroed counters
            self.record_sample(0.0);
        }
        if let Some(s) = self.sample_interval_ms {
            self.push_event(s, EventKind::Sample);
        }

        while let Some(ev) = self.events.pop() {
            let now = ev.key.at_ms;
            match ev.value {
                EventKind::Arrive { req, at } => self.handle_arrival(req, at, now),
                EventKind::Finish { server, dep } => self.handle_finish(server, dep, now),
                EventKind::SyncRound => {
                    self.run_sync_round(now);
                    if now < self.cfg.duration_ms * 1.5 {
                        self.push_event(now + interval, EventKind::SyncRound);
                    }
                }
                EventKind::PlacementRound => {
                    self.run_placement_round(now);
                    if let Some(p) = self.cfg.replacement_interval_ms {
                        if now < self.cfg.duration_ms {
                            self.push_event(now + p, EventKind::PlacementRound);
                            if let Some(sp) = self.predict.as_mut() {
                                sp.next_sched_round_ms = now + p;
                            }
                        } else if let Some(sp) = self.predict.as_mut() {
                            // no further scheduled round: nothing to pull
                            // forward, so the trigger goes quiet
                            sp.next_sched_round_ms = f64::INFINITY;
                        }
                    }
                }
                EventKind::Fault { idx } => {
                    // sample the counters at the instant *before* the
                    // action applies: phases close on pre-event state
                    self.record_sample(now);
                    let action = self.script[idx as usize].1;
                    self.apply_fault(action, now);
                }
                EventKind::Sample => {
                    if now <= self.cfg.duration_ms {
                        self.record_sample(now);
                        if let Some(s) = self.sample_interval_ms {
                            if now < self.cfg.duration_ms {
                                self.push_event(now + s, EventKind::Sample);
                            }
                        }
                    }
                }
            }
        }
        if self.sample_interval_ms.is_some() || !self.script.is_empty() {
            // final row: end-of-run counters labeled with the horizon
            self.record_sample(self.cfg.duration_ms);
        }
        self.metrics.duration_ms = self.cfg.duration_ms;
        self.account_capacity();
        &mut self.metrics
    }

    /// Consume the accumulated metrics without cloning (leaves empty
    /// metrics behind; the simulator is done at this point).
    pub fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }

    /// Fold a first-hop arrival into its category's forecaster and pull
    /// the next placement round forward when any category's projected
    /// demand at that round crosses provisioned capacity (§3.4, proactive
    /// variant — DESIGN.md §Prediction).  Only called with `predict` set.
    fn observe_arrival_forecast(&mut self, ri: usize, now: f64) {
        let service = self.slab[ri].service;
        let col = match self.svc_index.get(service) {
            Some(c) => c,
            None => return,
        };
        let mut trigger = false;
        if let Some(p) = self.predict.as_mut() {
            let cat = p.svc_cat[col] as usize;
            p.forecasters[cat].observe(now);
            if now >= p.next_allowed_ms && now < self.cfg.duration_ms {
                let horizon = p.next_sched_round_ms - now;
                if horizon > 0.0 && horizon.is_finite() {
                    for k in 0..4 {
                        if p.provisioned[k] <= 0.0 {
                            continue; // no baseline for this category yet
                        }
                        if let Some(rps) = p.forecasters[k].forecast_rps(horizon) {
                            if rps > p.provisioned[k] * (1.0 + p.cfg.margin) {
                                trigger = true;
                                break;
                            }
                        }
                    }
                }
                if trigger {
                    p.next_allowed_ms = now + p.cfg.cooldown_ms;
                }
            }
        }
        if trigger {
            self.metrics.pred_early_rounds += 1;
            self.run_placement_round(now);
        }
    }

    fn handle_arrival(&mut self, req_idx: u32, at: ServerId, now: f64) {
        let ri = req_idx as usize;
        if self.slab[ri].offloads == 0 {
            if self.cfg.replacement_interval_ms.is_some() {
                // first-hop arrivals feed the next placement round's R^T
                self.window_requests.push(req_idx);
            }
            if let Some(res) = self.resil.as_mut() {
                // each offered request refills the global retry budget
                res.budget.on_offered();
            }
            if self.predict.is_some() {
                self.observe_arrival_forecast(ri, now);
            }
        }
        let decision = match self.cfg.policy.offload {
            OffloadMode::Eq1 => {
                let view = SimView {
                    snap: &self.snap,
                    svc_index: &self.svc_index,
                    servers: &self.servers,
                    sync: &self.sync,
                    table: self.table,
                    now_ms: now,
                    n: self.servers.len(),
                    allow_cross_server: self.cfg.policy.allow_cross_server,
                    allow_device: self.cfg.policy.allow_device,
                };
                decide_with(
                    &self.slab[ri],
                    at,
                    now,
                    &view,
                    &self.cfg.handler,
                    &mut self.rng,
                    &mut self.offload_scratch,
                )
            }
            other => self.baseline_decide(ri, at, now, other),
        };

        let (service, offloads) = {
            let r = &self.slab[ri];
            (r.service, r.offloads)
        };
        match decision {
            Decision::Timeout => {
                self.metrics.record(service, &Outcome::Timeout, offloads)
            }
            Decision::OffloadExceeded => {
                self.metrics
                    .record(service, &Outcome::OffloadExceeded, offloads)
            }
            Decision::ResourceInsufficient => self.metrics.record(
                service,
                &Outcome::ResourceInsufficient,
                offloads,
            ),
            Decision::Local | Decision::CrossServerParallel => self.enqueue_local(
                req_idx,
                at,
                now,
                decision == Decision::CrossServerParallel,
            ),
            Decision::Device(dev) => self.enqueue_device(req_idx, at, dev, now),
            Decision::Offload(target) => {
                {
                    let r = &mut self.slab[ri];
                    r.offloads += 1;
                    r.path.push(at);
                }
                let spec = self.table.spec(service);
                // per-request scheduling latency of the policy, if any
                let sched = self.cfg.policy.central_latency_ms(self.servers.len());
                let transfer =
                    self.cloud.inter_server.transfer_ms(spec.payload_kb) + sched;
                self.push_event(
                    now + transfer,
                    EventKind::Arrive { req: req_idx, at: target },
                );
            }
        }
    }

    /// Baseline offload decisions (policies that don't use Eq. 1).
    fn baseline_decide(
        &self,
        req_idx: usize,
        at: ServerId,
        now: f64,
        mode: OffloadMode,
    ) -> Decision {
        let req = &self.slab[req_idx];
        let slo = self.table.spec(req.service).slo.latency_ms;
        if now - req.arrival_ms > slo {
            return Decision::Timeout;
        }
        let view = SimView {
            snap: &self.snap,
            svc_index: &self.svc_index,
            servers: &self.servers,
            sync: &self.sync,
            table: self.table,
            now_ms: now,
            n: self.servers.len(),
            allow_cross_server: self.cfg.policy.allow_cross_server,
            allow_device: self.cfg.policy.allow_device,
        };
        match view.local_capacity(at, req.service) {
            LocalCapacity::Ready => return Decision::Local,
            LocalCapacity::CrossServerParallel => {
                return Decision::CrossServerParallel
            }
            LocalCapacity::Device(d) => return Decision::Device(d),
            LocalCapacity::None => {}
        }
        match mode {
            OffloadMode::None => Decision::ResourceInsufficient,
            OffloadMode::RoundRobin => {
                if req.offloads >= self.cfg.handler.max_offloads {
                    return Decision::OffloadExceeded;
                }
                // InterEdge: forward to the next server in the ring
                let next = ServerId((at.0 + 1) % self.servers.len() as u32);
                if req.path.contains(&next) {
                    Decision::ResourceInsufficient
                } else {
                    Decision::Offload(next)
                }
            }
            OffloadMode::Centralized => {
                if req.offloads >= 1 {
                    // the central scheduler already routed it once
                    return Decision::ResourceInsufficient;
                }
                // global fresh view: pick the server with max idle capacity
                let mut best: Option<(ServerId, f64)> = None;
                for m in 0..self.servers.len() {
                    let mid = ServerId(m as u32);
                    if mid == at {
                        continue;
                    }
                    let e = view.entry(mid, req.service);
                    let idle = e.theoretical - e.actual;
                    if idle > 0.0 && best.is_none_or(|(_, b)| idle > b) {
                        best = Some((mid, idle));
                    }
                }
                match best {
                    Some((m, _)) => Decision::Offload(m),
                    None => Decision::ResourceInsufficient,
                }
            }
            OffloadMode::Eq1 => unreachable!(),
        }
    }

    fn enqueue_local(&mut self, req_idx: u32, at: ServerId, now: f64, cross: bool) {
        let (service, frames, offloads) = {
            let r = &self.slab[req_idx as usize];
            (r.service, r.frames, r.offloads)
        };
        let srv = &mut self.servers[at.0 as usize];
        // choose the matching deployment with minimum expected wait
        let mut best: Option<(usize, f64)> = None;
        for (i, d) in srv.deployments.iter().enumerate() {
            if d.service != service || d.cross_server != cross || d.retired {
                continue;
            }
            let wait = d.wait_from(now);
            if best.is_none_or(|(_, w)| wait < w) {
                best = Some((i, wait));
            }
        }
        // fall back to any live deployment of the service
        if best.is_none() {
            for (i, d) in srv.deployments.iter().enumerate() {
                if d.service == service && !d.retired {
                    let wait = d.wait_from(now);
                    if best.is_none_or(|(_, w)| wait < w) {
                        best = Some((i, wait));
                    }
                }
            }
        }
        let (dep, _) = match best {
            Some(b) => b,
            None => {
                self.metrics.record(
                    service,
                    &Outcome::ResourceInsufficient,
                    offloads,
                );
                return;
            }
        };
        {
            let d = &mut srv.deployments[dep];
            let svc_ms = d.service_ms(frames);
            d.queued_ms += svc_ms;
            d.queue.push_back(req_idx);
        }
        self.start_ready(at, dep, now, false);
    }

    fn enqueue_device(&mut self, req_idx: u32, at: ServerId, dev: DeviceId, now: f64) {
        let (service, frames, offloads) = {
            let r = &self.slab[req_idx as usize];
            (r.service, r.frames, r.offloads)
        };
        let srv = &mut self.servers[at.0 as usize];
        // device churn appends fresh deployments: target the live one
        if let Some(idx) = srv
            .device_deps
            .iter()
            .position(|(d, dep)| *d == dev && !dep.retired)
        {
            let d = &mut srv.device_deps[idx].1;
            let svc_ms = d.service_ms(frames);
            d.queued_ms += svc_ms;
            d.queue.push_back(req_idx);
            self.start_ready(at, idx, now, true);
        } else {
            self.metrics
                .record(service, &Outcome::ResourceInsufficient, offloads);
        }
    }

    /// Start queued requests while concurrency slots (Eq. 5) remain.
    fn start_ready(&mut self, at: ServerId, dep: usize, now: f64, device: bool) {
        loop {
            let d = if device {
                &mut self.servers[at.0 as usize].device_deps[dep].1
            } else {
                &mut self.servers[at.0 as usize].deployments[dep]
            };
            if d.in_flight >= d.cap {
                return;
            }
            let req_idx = match d.queue.pop_front() {
                Some(r) => r,
                None => return,
            };
            // `slab` and `servers` are disjoint fields: reading the request
            // while the deployment is mutably borrowed is fine.
            let (service, frames, arrival_ms, offloads) = {
                let r = &self.slab[req_idx as usize];
                (r.service, r.frames, r.arrival_ms, r.offloads)
            };
            let svc_ms = d.service_ms(frames);
            d.queued_ms = (d.queued_ms - svc_ms).max(0.0);

            let spec = self.table.spec(service);
            // execution cannot begin before the model finished loading
            let start = now.max(d.available_at_ms);

            // SLO base for the deadline budget: frequency streams amortize
            // over the whole stream duration, mirroring the gateway
            let latency_task = matches!(spec.sensitivity, Sensitivity::Latency);
            let deadline = {
                let slo_ms = match (latency_task, spec.slo.min_rate) {
                    (false, Some(rate)) if rate > 0.0 => {
                        spec.slo.latency_ms.max(frames as f64 * 1000.0 / rate)
                    }
                    _ => spec.slo.latency_ms,
                };
                arrival_ms + resilience::deadline_budget_ms(latency_task, slo_ms)
            };
            let bkey = (at.0, service.0);
            if self.resil.is_some() {
                // deadline pre-drop: doomed work is dropped before it
                // occupies a concurrency slot (the gateway's fast 504)
                if start > deadline {
                    self.metrics.deadline_expired += 1;
                    self.metrics.record(service, &Outcome::Timeout, offloads);
                    continue;
                }
                // open breaker short-circuits without executing
                let res = self.resil.as_mut().unwrap();
                let b = res
                    .breakers
                    .entry(bkey)
                    .or_insert_with(|| Breaker::new(&self.cfg.resilience));
                if let resilience::Admit::ShortCircuit { .. } = b.admit(now) {
                    self.metrics.breaker_short_circuits += 1;
                    self.metrics
                        .record(service, &Outcome::ResourceInsufficient, offloads);
                    continue;
                }
            }
            d.in_flight += 1;

            // execution proper: possibly slowed, faulted, and retried.
            // `exec_ms`/`attempts` reduce to `svc_ms`/1 bit-for-bit when
            // no fault window is active, so fault-free runs reproduce the
            // historical timing exactly.
            let exec_ms = if self.exec_slow_factor != 1.0 {
                svc_ms * self.exec_slow_factor
            } else {
                svc_ms
            };
            let mut attempts = 1.0f64;
            let mut backoff_ms = 0.0;
            let mut faulted = self.exec_fault_rate > 0.0
                && self.fault_rng.chance(self.exec_fault_rate);
            let mut expired_mid_retry = false;
            if faulted {
                if let Some(res) = self.resil.as_mut() {
                    // bounded retries: latency-critical gets one hedged
                    // attempt, frequency traffic up to max_retries
                    let max_extra = if latency_task {
                        1
                    } else {
                        self.cfg.resilience.max_retries
                    };
                    let mut prev = 0.0;
                    let mut extra = 0u32;
                    while faulted && extra < max_extra {
                        if !res.budget.try_take() {
                            break;
                        }
                        prev = resilience::decorrelated_jitter(
                            &mut self.fault_rng,
                            prev,
                            self.cfg.resilience.backoff_base_ms,
                            self.cfg.resilience.backoff_cap_ms,
                        );
                        if start + exec_ms * (attempts + 1.0) + backoff_ms + prev
                            > deadline
                        {
                            expired_mid_retry = true;
                            break;
                        }
                        backoff_ms += prev;
                        attempts += 1.0;
                        extra += 1;
                        self.metrics.retries += 1;
                        faulted = self.fault_rng.chance(self.exec_fault_rate);
                    }
                }
            }

            let done_at = start + exec_ms * attempts + backoff_ms;
            let latency = done_at - arrival_ms;
            let outcome = if faulted || expired_mid_retry {
                if let Some(res) = self.resil.as_mut() {
                    if let Some(b) = res.breakers.get_mut(&bkey) {
                        if b.record(now, false) {
                            self.metrics.breaker_trips += 1;
                        }
                    }
                }
                if expired_mid_retry {
                    self.metrics.deadline_expired += 1;
                    Outcome::Timeout
                } else {
                    Outcome::ResourceInsufficient
                }
            } else {
                if let Some(res) = self.resil.as_mut() {
                    if let Some(b) = res.breakers.get_mut(&bkey) {
                        b.record(now, true);
                    }
                }
                match spec.sensitivity {
                    Sensitivity::Latency => {
                        if latency <= spec.slo.latency_ms {
                            Outcome::Completed { latency_ms: latency }
                        } else {
                            Outcome::Timeout
                        }
                    }
                    Sensitivity::Frequency => {
                        let target = spec.slo.min_rate.unwrap_or(30.0);
                        // achieved rate across the whole request lifetime
                        let achieved =
                            frames as f64 / (latency / 1000.0).max(1e-9);
                        if achieved >= target {
                            Outcome::Completed { latency_ms: latency }
                        } else {
                            let frac = (achieved / target).min(1.0);
                            Outcome::Partial {
                                satisfied: frac * frames as f64,
                                total: frames,
                            }
                        }
                    }
                }
            };
            self.metrics.record(service, &outcome, offloads);
            if let Some(li) = self.svc_index.get(service) {
                *self.window_done.get_mut(at.0 as usize, li) += outcome.credit();
            }

            if !device {
                // GPU-time: this request's share of its batch windows;
                // exclusive (no-MT) deployments hold the whole GPU
                let al = &self.allocs[&service];
                let slice = if al.exclusive_gpu {
                    1.0
                } else {
                    self.table.spec(service).compute_slice.min(1.0)
                };
                let share = 1.0 / self.servers[at.0 as usize].deployments[dep]
                    .cap.max(1) as f64;
                // retried attempts burn real GPU time (backoff does not)
                self.metrics.gpu_busy_ms +=
                    exec_ms * attempts * al.ops.gpus() as f64 * slice * share;
            }
            self.push_event(
                done_at,
                EventKind::Finish {
                    server: at,
                    dep: if device {
                        dep as u32 | DEVICE_FLAG
                    } else {
                        dep as u32
                    },
                },
            );
        }
    }

    /// Coarse-grained re-placement (§3.4): recompute Θ from the last
    /// interval's arrivals, retire deployments the new Θ drops, and
    /// install the additions with their model-load delay (Fig. 3f).
    fn run_placement_round(&mut self, now: f64) {
        if self.window_requests.is_empty() {
            return;
        }
        // demand = arrivals / elapsed since the last consumed window —
        // NOT the nominal interval: a recovery-triggered round lands
        // mid-interval over a partial window, and scaling that by the
        // full interval would underestimate demand several-fold
        let span = (now - self.last_round_ms).max(1.0);
        self.last_round_ms = now;
        let window = std::mem::take(&mut self.window_requests);
        if let Some(p) = self.predict.as_mut() {
            // re-baseline: what this round provisions for, per category —
            // the proactive trigger compares forecasts against these
            let mut counts = [0.0f64; 4];
            for &i in &window {
                if let Some(col) = self.svc_index.get(self.slab[i as usize].service) {
                    counts[p.svc_cat[col] as usize] += 1.0;
                }
            }
            for (k, &c) in counts.iter().enumerate() {
                p.provisioned[k] = c * 1000.0 / span;
            }
        }
        let services: Vec<ServiceId> = {
            let mut s: Vec<ServiceId> = window
                .iter()
                .map(|&i| self.slab[i as usize].service)
                .collect();
            s.sort();
            s.dedup();
            s
        };
        let mut eval = FluidEval::from_demand(
            self.table,
            &self.allocs,
            &self.cloud,
            window.iter().map(|&i| &self.slab[i as usize]),
            span,
        );
        // Cache-warmth preference: bias the greedy toward servers already
        // holding the weights, so this round's additions avoid cold loads.
        if let Some(fabric) = self.cache.as_ref() {
            eval.set_warmth(self.cfg.cache.warmth_weight, |server, svc| {
                fabric.warm_frac(ServerId(server as u32), svc)
            });
        }
        let new_placement = sssp(&[], &services, self.cloud.n_servers(), &mut eval);

        // diff: count deployments per (service, server) old vs new — dense
        // (server × service) grid, so the additions below come out in a
        // deterministic (server, service-id) order, unlike the former
        // HashMap iteration.
        let ns = self.svc_index.len();
        let mut want = vec![0i32; self.servers.len() * ns];
        let mut eps_cursor = 0usize;
        for item in &new_placement {
            let server = if item.server == EPSILON_SERVER {
                self.next_eps_server(&mut eps_cursor).0 as usize
            } else {
                item.server.0 as usize
            };
            if let Some(li) = self.svc_index.get(item.service) {
                want[server * ns + li] += 1;
            }
        }
        // retire surplus live deployments, compute additions
        for (si, srv) in self.servers.iter_mut().enumerate() {
            for d in srv.deployments.iter_mut() {
                if d.retired {
                    continue;
                }
                match self.svc_index.get(d.service) {
                    Some(li) => {
                        let c = &mut want[si * ns + li];
                        if *c > 0 {
                            *c -= 1; // kept (no reload needed)
                        } else {
                            d.retired = true;
                        }
                    }
                    None => d.retired = true,
                }
            }
        }
        let mut additions: Vec<PlacementItem> = Vec::new();
        for si in 0..self.servers.len() {
            for li in 0..ns {
                for _ in 0..want[si * ns + li].max(0) {
                    additions.push(PlacementItem {
                        service: self.svc_index.id_at(li),
                        server: ServerId(si as u32),
                    });
                }
            }
        }
        self.placement_applied_at_ms = now;
        self.materialize_placement(&additions);
        self.placement.extend(additions);
        self.prime_snapshot();
    }

    fn handle_finish(&mut self, server: ServerId, dep: u32, now: f64) {
        let device = dep & DEVICE_FLAG != 0;
        let idx = (dep & !DEVICE_FLAG) as usize;
        {
            let d = if device {
                &mut self.servers[server.0 as usize].device_deps[idx].1
            } else {
                &mut self.servers[server.0 as usize].deployments[idx]
            };
            d.in_flight = d.in_flight.saturating_sub(1);
        }
        self.start_ready(server, idx, now, device);
    }

    /// Complete a sync round: refresh snapshots of actual goodput and
    /// queue depths (this is what makes the handler's view *stale*).
    /// Allocation-free: the per-service accumulators are reused scratch
    /// vectors, and the window counters are a flat grid reset in place.
    fn run_sync_round(&mut self, now: f64) {
        let window_s = ((now - self.last_sync_ms) / 1000.0).max(1e-9);
        let ns = self.svc_index.len();
        for si in 0..self.servers.len() {
            self.scratch_theo[..ns].fill(0.0);
            self.scratch_queued[..ns].fill(0.0);
            self.scratch_seen[..ns].fill(false);
            for d in &self.servers[si].deployments {
                if d.retired && d.queue.is_empty() {
                    continue;
                }
                let Some(li) = self.svc_index.get(d.service) else {
                    continue;
                };
                self.scratch_seen[li] = true;
                if !d.retired {
                    self.scratch_theo[li] += d.req_rate;
                }
                self.scratch_queued[li] += d.queued_ms / d.cap.max(1) as f64;
            }
            for li in 0..ns {
                if self.scratch_seen[li] {
                    let done = *self.window_done.get(si, li);
                    *self.snap.get_mut(si, li) = SyncedEntry {
                        theoretical: self.scratch_theo[li],
                        actual: done / window_s,
                        queued_ms: self.scratch_queued[li],
                    };
                }
            }
        }
        self.window_done.fill(0.0);
        self.last_sync_ms = now;
        self.sync.advance(now);
    }

    fn account_capacity(&mut self) {
        let dur = self.cfg.duration_ms;
        let gpus = self.cloud.healthy_gpus() as f64;
        self.metrics.gpu_capacity_ms = gpus * dur;
        let vram_total: f64 = self
            .cloud
            .servers
            .iter()
            .flat_map(|s| s.gpus.iter())
            .filter(|g| !g.failed)
            .map(|g| g.spec.vram_mb)
            .sum();
        self.metrics.vram_capacity_mb_ms = vram_total * dur;
        // VRAM in use = resident placements over the whole run
        let mut used = 0.0;
        for srv in &self.servers {
            for d in &srv.deployments {
                let al = &self.allocs[&d.service];
                used += self.table.vram_per_gpu(d.service, al.ops.mp)
                    * al.ops.gpus() as f64;
            }
        }
        self.metrics.vram_used_mb_ms = used.min(vram_total) * dur;
    }

    /// Access to the sync substrate for fault-injection experiments.
    pub fn sync_mut(&mut self) -> &mut SyncNet {
        &mut self.sync
    }

    // ----------------------------------------------------------------------
    // scripted faults + sampling (the scenario engine's injection surface)
    // ----------------------------------------------------------------------

    /// Schedule a scripted action at virtual time `at_ms`.  Call before
    /// [`Simulator::run`]; actions are injected into the event heap and
    /// interleave with the trace deterministically (time, then schedule
    /// order on ties).
    pub fn schedule_fault(&mut self, at_ms: f64, action: FaultAction) {
        self.script.push((at_ms, action));
    }

    /// Record a [`SimSample`] every `every_ms` of virtual time, in
    /// addition to the sample taken at every scripted action and the
    /// final one at the horizon.
    pub fn sample_every(&mut self, every_ms: f64) {
        self.sample_interval_ms = Some(every_ms.max(1.0));
    }

    /// Samples collected by the last [`Simulator::run`].
    pub fn samples(&self) -> &[SimSample] {
        &self.samples
    }

    /// Live (non-retired) server deployments currently hosted by `server`
    /// (device-backed deployments not included).
    pub fn live_deployments(&self, server: ServerId) -> usize {
        self.servers[server.0 as usize]
            .deployments
            .iter()
            .filter(|d| !d.retired)
            .count()
    }

    fn record_sample(&mut self, now: f64) {
        self.samples.push(SimSample {
            at_ms: now,
            offered: self.metrics.offered,
            satisfied: self.metrics.satisfied,
            completed: self.metrics.completed,
            timeout: self.metrics.timeout,
            offload_exceeded: self.metrics.offload_exceeded,
            resource_insufficient: self.metrics.resource_insufficient,
            cache_hits: self.metrics.cache_hits,
            cache_partial: self.metrics.cache_partial,
            cache_misses: self.metrics.cache_misses,
            cache_bytes_loaded_mb: self.metrics.cache_bytes_loaded_mb,
            cache_bytes_saved_mb: self.metrics.cache_bytes_saved_mb,
            retries: self.metrics.retries,
            deadline_expired: self.metrics.deadline_expired,
            breaker_trips: self.metrics.breaker_trips,
            breaker_short_circuits: self.metrics.breaker_short_circuits,
            pred_early_rounds: self.metrics.pred_early_rounds,
        });
    }

    fn apply_fault(&mut self, action: FaultAction, now: f64) {
        match action {
            FaultAction::FailServer(s) => self.fail_server(s),
            FaultAction::RecoverServer(s) => self.recover_server(s, now),
            FaultAction::DeviceLeave(d) => self.device_leave(d),
            FaultAction::DeviceJoin(d) => self.device_join(d),
            FaultAction::LatencySkew { server, factor } => {
                self.skew_server(server, factor)
            }
            FaultAction::Checkpoint => {}
            FaultAction::ExecFaultRate { rate } => {
                self.exec_fault_rate = rate.clamp(0.0, 1.0);
            }
            FaultAction::ExecSlowdown { factor } => {
                self.exec_slow_factor = if factor > 0.0 { factor } else { 1.0 };
            }
        }
    }

    /// Drained queue entries terminate as `ResourceInsufficient`.
    fn record_insufficient(&mut self, drained: &[u32]) {
        for &ri in drained {
            let (svc, off) = {
                let r = &self.slab[ri as usize];
                (r.service, r.offloads)
            };
            self.metrics.record(svc, &Outcome::ResourceInsufficient, off);
        }
    }

    /// Inject a GPU failure (§5.3.3): the whole server's deployments of
    /// co-parallel GPUs are terminated and excluded.  Kept as the
    /// historical name; [`Simulator::fail_server`] is the general path.
    pub fn fail_gpu_containment(&mut self, server: ServerId) {
        self.fail_server(server);
    }

    /// Whole-server GPU outage (§5.3.3 generalized, mid-run safe): live
    /// deployments retire (their roster is stashed for recovery), queued
    /// requests drain as `ResourceInsufficient`, in-flight batches finish
    /// (containment lets running work complete), GPUs flag failed, and
    /// the sync ring marks the server down.
    pub fn fail_server(&mut self, server: ServerId) {
        let si = server.0 as usize;
        let mut drained: Vec<u32> = Vec::new();
        let mut stash: Vec<StashedDep> = Vec::new();
        {
            let srv = &mut self.servers[si];
            for d in srv.deployments.iter_mut() {
                if !d.retired {
                    stash.push(StashedDep {
                        service: d.service,
                        cross: d.cross_server,
                    });
                    d.retired = true;
                }
                d.queued_ms = 0.0;
                drained.extend(d.queue.drain(..));
            }
            for (_, d) in srv.device_deps.iter_mut() {
                // the home server coordinates its devices: outage takes
                // their lanes down too (devices re-install on recovery)
                d.retired = true;
                d.queued_ms = 0.0;
                drained.extend(d.queue.drain(..));
            }
        }
        self.record_insufficient(&drained);
        if !stash.is_empty() {
            // a repeated fail on an already-dark server must not wipe the
            // roster stashed by the first one
            self.stash[si] = stash;
        }
        for g in &mut self.cloud.servers[si].gpus {
            g.failed = true;
        }
        // synced state zeroes out at the next round; mark immediately to
        // prevent fault propagation
        for e in self.snap.row_mut(si) {
            e.theoretical = 0.0;
            e.actual = 0.0;
            e.queued_ms = 0.0;
        }
        // VRAM does not survive a crash: the weight cache goes cold, so
        // post-recovery loads start from scratch (cache invariant in
        // DESIGN.md §Model cache).  Device churn does NOT touch it.
        if let Some(fabric) = self.cache.as_mut() {
            fabric.invalidate(server);
        }
        self.sync.mark_down(server);
    }

    /// Bring a failed server back (§5.3.3 "manual intervention"): GPUs
    /// heal and the ring repairs.  Service is restored by an immediate
    /// re-placement round when periodic re-placement is active (the
    /// solver sees the healthy GPUs again), else by reinstating the
    /// roster stashed at failure; both pay the Fig. 3f model-load delay.
    pub fn recover_server(&mut self, server: ServerId, now: f64) {
        let si = server.0 as usize;
        for g in &mut self.cloud.servers[si].gpus {
            g.failed = false;
        }
        self.sync.repair(server, now);
        let stash = std::mem::take(&mut self.stash[si]);
        if self.cfg.replacement_interval_ms.is_some()
            && !self.window_requests.is_empty()
        {
            self.run_placement_round(now);
        } else {
            self.placement_applied_at_ms = now;
            for s in &stash {
                self.spawn_deployment(server, s.service, s.cross);
            }
            self.prime_snapshot();
        }
        if self.cfg.policy.allow_device {
            let devices: Vec<(DeviceId, GpuSpec)> = self
                .cloud
                .devices
                .iter()
                .filter(|d| d.registered && d.home == server)
                .filter_map(|d| d.kind.gpu().map(|g| (d.id, g)))
                .collect();
            for (dev, gpu) in devices {
                self.install_device(dev, server, gpu);
            }
        }
    }

    /// Edge device deregisters (§3.2 churn): its deployments retire and
    /// their queues drain as `ResourceInsufficient`.
    pub fn device_leave(&mut self, dev: DeviceId) {
        if let Some(d) = self.cloud.devices.iter_mut().find(|d| d.id == dev) {
            d.registered = false;
        }
        let mut drained: Vec<u32> = Vec::new();
        for srv in self.servers.iter_mut() {
            for (id, dep) in srv.device_deps.iter_mut() {
                if *id == dev && !dep.retired {
                    dep.retired = true;
                    dep.queued_ms = 0.0;
                    drained.extend(dep.queue.drain(..));
                }
            }
        }
        self.record_insufficient(&drained);
    }

    /// Edge device (re)registers with its home server and contributes a
    /// deployment again (no-op while the home server is down — the
    /// device re-installs on server recovery).
    pub fn device_join(&mut self, dev: DeviceId) {
        if !self.cfg.policy.allow_device {
            return;
        }
        let info = self.cloud.devices.iter_mut().find(|d| d.id == dev).map(|d| {
            d.registered = true;
            (d.home, d.kind)
        });
        if let Some((home, kind)) = info {
            if self.sync.is_down(home) {
                return;
            }
            if let Some(gpu) = kind.gpu() {
                self.install_device(dev, home, gpu);
            }
        }
    }

    /// Multiply the batch-window time of every live deployment on the
    /// server by `factor` (> 1 slows, < 1 undoes an earlier skew).  The
    /// synced theoretical rate follows at the next sync round.  The
    /// server's composite skew is tracked, and deployments installed
    /// while a skew is active inherit it — so the paired revert
    /// (×1/factor) is correct for them as well.
    pub fn skew_server(&mut self, server: ServerId, factor: f64) {
        let f = factor.max(1e-3);
        let si = server.0 as usize;
        let mut composite = self.server_skew[si] * f;
        if (composite - 1.0).abs() < 1e-9 {
            composite = 1.0; // snap f64 residue from factor × 1/factor
        }
        self.server_skew[si] = composite;
        let srv = &mut self.servers[si];
        for d in srv.deployments.iter_mut().filter(|d| !d.retired) {
            d.window_ms = (d.window_ms * f).max(1e-3);
            d.req_rate /= f;
        }
        for (_, d) in srv.device_deps.iter_mut() {
            if !d.retired {
                d.window_ms = (d.window_ms * f).max(1e-3);
                d.req_rate /= f;
            }
        }
    }
}

/// Convenience: run one end-to-end simulation.
pub fn simulate(
    table: &ProfileTable,
    cloud: EdgeCloud,
    requests: Vec<Request>,
    cfg: SimConfig,
) -> Metrics {
    let mut sim = Simulator::new(table, cloud, &requests, cfg);
    sim.run(requests);
    sim.take_metrics()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::zoo;
    use crate::workload::{generate, Mix, WorkloadSpec};

    fn run_mix(mix: Mix, rps: f64, policy: PolicyConfig) -> Metrics {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec {
            mix,
            rps,
            duration_ms: 20_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let cfg = SimConfig {
            policy,
            duration_ms: 20_000.0,
            ..Default::default()
        };
        simulate(&table, cloud, reqs, cfg)
    }

    #[test]
    fn light_load_high_satisfaction() {
        let m = run_mix(Mix::Production(0), 5.0, PolicyConfig::epara());
        assert!(m.offered > 20);
        assert!(
            m.satisfaction_ratio() > 0.9,
            "ratio {} of {}",
            m.satisfaction_ratio(),
            m.offered
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_mix(Mix::Production(0), 20.0, PolicyConfig::epara());
        let b = run_mix(Mix::Production(0), 20.0, PolicyConfig::epara());
        assert_eq!(a.offered, b.offered);
        assert!((a.satisfied - b.satisfied).abs() < 1e-9);
    }

    #[test]
    fn overload_degrades_gracefully() {
        let light = run_mix(Mix::Production(0), 10.0, PolicyConfig::epara());
        let heavy = run_mix(Mix::Production(0), 400.0, PolicyConfig::epara());
        // goodput must not collapse under 10× overload (Fig. 18e)
        assert!(heavy.goodput_rps() >= light.goodput_rps() * 0.8,
                "heavy {} light {}", heavy.goodput_rps(), light.goodput_rps());
        assert!(heavy.satisfaction_ratio() < light.satisfaction_ratio());
    }

    #[test]
    fn epara_beats_no_offload_baseline() {
        // Fig. 17a: request handling (offloading) matters
        let epara = run_mix(Mix::Production(0), 120.0, PolicyConfig::epara());
        let pinned = run_mix(Mix::Production(0), 120.0, PolicyConfig::epara_no_offload());
        assert!(
            epara.satisfied > pinned.satisfied,
            "epara {} <= pinned {}",
            epara.satisfied,
            pinned.satisfied
        );
    }

    #[test]
    fn gpu_failure_containment() {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec { rps: 30.0, duration_ms: 10_000.0, ..Default::default() };
        let reqs = generate(&spec, &table, &cloud);
        let cfg = SimConfig { duration_ms: 10_000.0, ..Default::default() };
        let mut sim = Simulator::new(&table, cloud, &reqs, cfg);
        sim.fail_gpu_containment(ServerId(0));
        let m = sim.run(reqs).clone();
        // the system keeps serving from the remaining servers
        assert!(m.satisfied > 0.0);
    }

    #[test]
    fn scripted_fault_samples_and_recovery() {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 40.0,
            duration_ms: 12_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let cfg = SimConfig { duration_ms: 12_000.0, ..Default::default() };
        let mut sim = Simulator::new(&table, cloud, &reqs, cfg);
        assert!(sim.live_deployments(ServerId(0)) > 0);
        sim.schedule_fault(3_000.0, FaultAction::FailServer(ServerId(0)));
        sim.schedule_fault(6_000.0, FaultAction::RecoverServer(ServerId(0)));
        sim.sample_every(1_000.0);
        sim.run(reqs);
        // offline mode: recovery reinstates the failed roster
        assert!(sim.live_deployments(ServerId(0)) > 0);
        let samples = sim.samples();
        assert!(samples.len() >= 12, "{}", samples.len());
        // samples are time-sorted with monotone cumulative counters
        for w in samples.windows(2) {
            assert!(w[0].at_ms <= w[1].at_ms);
            assert!(w[0].offered <= w[1].offered);
            assert!(w[0].satisfied <= w[1].satisfied + 1e-12);
        }
        assert!(sim.metrics.satisfied > 0.0);
    }

    #[test]
    fn failed_server_stays_dark_without_recovery() {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 40.0,
            duration_ms: 10_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let cfg = SimConfig {
            duration_ms: 10_000.0,
            replacement_interval_ms: Some(2_500.0),
            ..Default::default()
        };
        let mut sim = Simulator::new(&table, cloud, &reqs, cfg);
        sim.schedule_fault(3_000.0, FaultAction::FailServer(ServerId(0)));
        sim.run(reqs);
        // periodic re-placement must not resurrect a down server
        assert_eq!(sim.live_deployments(ServerId(0)), 0);
        assert!(sim.metrics.satisfied > 0.0);
    }

    #[test]
    fn device_churn_round_trips() {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec { rps: 20.0, duration_ms: 8_000.0, ..Default::default() };
        let reqs = generate(&spec, &table, &cloud);
        let cfg = SimConfig { duration_ms: 8_000.0, ..Default::default() };
        let mut sim = Simulator::new(&table, cloud, &reqs, cfg);
        // device 2 (Alveo U50 @ server 5) is the GPU-bearing one
        sim.schedule_fault(2_000.0, FaultAction::DeviceLeave(DeviceId(2)));
        sim.schedule_fault(4_000.0, FaultAction::DeviceJoin(DeviceId(2)));
        let skew = |f: f64| FaultAction::LatencySkew { server: ServerId(1), factor: f };
        sim.schedule_fault(5_000.0, skew(2.0));
        sim.schedule_fault(6_000.0, skew(0.5));
        sim.run(reqs);
        assert!(sim.metrics.satisfied > 0.0);
    }

    /// One run under a scripted executor-fault window; resilience on/off.
    fn run_flaky(resilience_on: bool, rate: f64) -> Metrics {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 30.0,
            duration_ms: 12_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let mut cfg = SimConfig { duration_ms: 12_000.0, ..Default::default() };
        cfg.resilience.enabled = resilience_on;
        let mut sim = Simulator::new(&table, cloud, &reqs, cfg);
        sim.schedule_fault(2_000.0, FaultAction::ExecFaultRate { rate });
        sim.schedule_fault(8_000.0, FaultAction::ExecFaultRate { rate: 0.0 });
        sim.run(reqs);
        sim.take_metrics()
    }

    #[test]
    fn exec_fault_injection_is_deterministic_and_gated() {
        let a = run_flaky(false, 0.3);
        let b = run_flaky(false, 0.3);
        // same seed, same script → bit-identical runs, faults included
        assert_eq!(a.fingerprint(), b.fingerprint());
        // the fault window fails real work...
        let clean = run_mix(Mix::Production(0), 30.0, PolicyConfig::epara());
        assert!(a.resource_insufficient > clean.resource_insufficient);
        assert!(a.satisfied < clean.satisfied);
        // ...but with resilience off, no retries happen and the
        // fingerprint stays free of the gated resilience section
        assert_eq!(a.retries, 0);
        assert!(!a.fingerprint().contains("res["));
    }

    #[test]
    fn resilience_recovers_goodput_under_exec_faults() {
        let off = run_flaky(false, 0.3);
        let on = run_flaky(true, 0.3);
        assert_eq!(on.offered, off.offered, "equal offered load");
        assert!(on.retries > 0, "retries {}", on.retries);
        assert!(
            on.satisfied > off.satisfied,
            "resilience-on {} must beat off {}",
            on.satisfied,
            off.satisfied
        );
        assert!(on.fingerprint().contains("res["));
    }

    /// A two-phase trace (calm, then 4× surge at 10 s) under periodic
    /// re-placement, with the prediction layer on or off.
    fn run_surge(predict_on: bool) -> Metrics {
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let calm = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 20.0,
            duration_ms: 10_000.0,
            ..Default::default()
        };
        let hot = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 80.0,
            duration_ms: 10_000.0,
            seed: 2,
            ..Default::default()
        };
        let mut reqs = generate(&calm, &table, &cloud);
        let mut surge = generate(&hot, &table, &cloud);
        for r in surge.iter_mut() {
            r.arrival_ms += 10_000.0;
        }
        reqs.append(&mut surge);
        let mut cfg = SimConfig {
            duration_ms: 20_000.0,
            replacement_interval_ms: Some(5_000.0),
            ..Default::default()
        };
        cfg.predict.enabled = predict_on;
        simulate(&table, cloud, reqs, cfg)
    }

    #[test]
    fn prediction_without_replacement_rounds_stays_inert() {
        // enabled but no periodic re-placement: there is no scheduled
        // round to pull forward, so the layer never constructs and the
        // fingerprint matches a predict-off run byte-for-byte
        let table = zoo::paper_zoo();
        let cloud = EdgeCloud::testbed();
        let spec = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 30.0,
            duration_ms: 10_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let mut cfg = SimConfig { duration_ms: 10_000.0, ..Default::default() };
        cfg.predict.enabled = true;
        let on = simulate(&table, cloud.clone(), reqs.clone(), cfg);
        let off = simulate(&table, cloud, reqs, SimConfig {
            duration_ms: 10_000.0,
            ..Default::default()
        });
        assert_eq!(on.pred_early_rounds, 0);
        assert!(!on.fingerprint().contains("pred["));
        assert_eq!(on.fingerprint(), off.fingerprint());
    }

    #[test]
    fn forecast_triggers_early_rounds_deterministically() {
        let off = run_surge(false);
        assert_eq!(off.pred_early_rounds, 0);
        assert!(!off.fingerprint().contains("pred["));
        let on = run_surge(true);
        assert_eq!(on.offered, off.offered, "equal offered load");
        assert!(
            on.pred_early_rounds >= 1,
            "the 4× surge must pull a round forward: {}",
            on.pred_early_rounds
        );
        assert!(on.fingerprint().contains("pred[er="));
        // same seed, same trace → bit-identical, triggers included
        let again = run_surge(true);
        assert_eq!(on.fingerprint(), again.fingerprint());
    }

    #[test]
    fn total_fault_window_trips_breakers_and_short_circuits() {
        let m = run_flaky(true, 1.0);
        // a 6 s window of certain failure must open at least one breaker
        // and fast-fail at least one request against it
        assert!(m.breaker_trips >= 1, "trips {}", m.breaker_trips);
        assert!(
            m.breaker_short_circuits >= 1,
            "short circuits {}",
            m.breaker_short_circuits
        );
        // service recovers once the window clears
        assert!(m.satisfied > 0.0);
    }
}
