//! Policy knobs: EPARA and the comparison baselines behind one config.
//!
//! Table 3's scheme matrix, operationalized.  Every baseline runs on the
//! SAME simulator engine; only allocation operators, offload mode,
//! placement mode, and central-scheduler latency differ — so measured
//! gaps are due to the paper's design choices, not bookkeeping.
//!
//! | scheme        | request-level | service-level | mode         |
//! |---------------|---------------|---------------|--------------|
//! | InterEdge     | no            | MP+BS+MT (as EPARA) | distributed, round-robin offload |
//! | AlpaServe     | no            | MP+           | centralized, refuses offloading |
//! | Galaxy        | no            | MP (no MT)    | centralized edge devices |
//! | SERV-P        | no            | no            | centralized NP-hard solver (latency penalty) |
//! | USHER         | no            | MP+MT         | centralized |
//! | DeTransformer | no            | MP only       | centralized |
//! | EPARA         | DP+MF         | MP+BS+MT      | mixed        |

use crate::allocator::Allocation;
use crate::core::MpKind;
use crate::placement::cache_baselines::CachePolicy;

/// How requests leave a saturated server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OffloadMode {
    /// EPARA's Eq. (1) probabilistic idle-goodput draw.
    Eq1,
    /// InterEdge: forward to the ring successor.
    RoundRobin,
    /// AlpaServe / USHER / DeTransformer: no inter-server offloading.
    None,
    /// Galaxy / SERV-P: an omniscient central scheduler routes once.
    Centralized,
}

/// Placement strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementMode {
    /// EPARA's Algorithm 1 (submodular, three stages).
    Sssp,
    /// Cache-policy baseline (Fig. 17b).
    Cache(CachePolicy),
    /// Demand-greedy without the ε stage (datacenter schemes).
    LocalOnly,
}

/// Full policy configuration.
#[derive(Clone, Copy, Debug)]
pub struct PolicyConfig {
    pub name: &'static str,
    pub offload: OffloadMode,
    pub placement: PlacementMode,
    /// Request-level operators (MF + DP) enabled?
    pub request_level: bool,
    /// Multi-task (MT) packing enabled?
    pub mt_enabled: bool,
    /// Batching (BS) enabled?
    pub bs_enabled: bool,
    /// Model parallelism enabled?
    pub mp_enabled: bool,
    /// Cross-server parallel deployments allowed (ε stage)?
    pub allow_cross_server: bool,
    /// Edge-device GPU registration allowed?
    pub allow_device: bool,
    /// Per-request central-scheduler latency: a + b·n ms for n servers
    /// (Fig. 3e's scaling; zero for decentralized schemes).
    pub central_lat_base_ms: f64,
    pub central_lat_per_server_ms: f64,
}

impl PolicyConfig {
    /// Central-scheduler latency for `n` servers (0 for decentralized).
    pub fn central_latency_ms(&self, n: usize) -> f64 {
        if self.central_lat_per_server_ms == 0.0 && self.central_lat_base_ms == 0.0 {
            return 0.0;
        }
        self.central_lat_base_ms + self.central_lat_per_server_ms * n as f64
    }

    /// Strip operators this policy does not implement.
    pub fn adjust_allocation(&self, al: &mut Allocation) {
        if !self.request_level {
            al.ops.mf = 1;
            al.ops.dp = 1;
        }
        if !self.mt_enabled {
            al.ops.mt = 1;
            // no MPS packing: every deployment owns its GPUs outright
            al.exclusive_gpu = true;
        }
        if !self.bs_enabled {
            al.ops.bs = 1;
        }
        if !self.mp_enabled {
            al.ops.mp = MpKind::None;
        }
    }

    pub fn epara() -> Self {
        PolicyConfig {
            name: "EPARA",
            offload: OffloadMode::Eq1,
            placement: PlacementMode::Sssp,
            request_level: true,
            mt_enabled: true,
            bs_enabled: true,
            mp_enabled: true,
            allow_cross_server: true,
            allow_device: true,
            central_lat_base_ms: 0.0,
            central_lat_per_server_ms: 0.0,
        }
    }

    /// Ablation: EPARA with offloading disabled (Fig. 17a's "first hop
    /// only" comparison).
    pub fn epara_no_offload() -> Self {
        PolicyConfig {
            name: "EPARA-no-offload",
            offload: OffloadMode::None,
            ..Self::epara()
        }
    }

    /// Ablation: EPARA with a cache placement (Fig. 17b).
    pub fn epara_cache_placement(policy: CachePolicy) -> Self {
        PolicyConfig {
            name: "EPARA-cache",
            placement: PlacementMode::Cache(policy),
            ..Self::epara()
        }
    }

    /// InterEdge: decentralized round-robin forwarding; MP/BS/MT aligned
    /// with EPARA (§5.1 comparisons), no request-level operators.
    pub fn interedge() -> Self {
        PolicyConfig {
            name: "InterEdge",
            offload: OffloadMode::RoundRobin,
            placement: PlacementMode::LocalOnly,
            request_level: false,
            ..Self::epara()
        }
    }

    /// AlpaServe: datacenter statistical multiplexing; refuses requests
    /// needing offload or cross-edge parallelism.
    pub fn alpaserve() -> Self {
        PolicyConfig {
            name: "AlpaServe",
            offload: OffloadMode::None,
            placement: PlacementMode::LocalOnly,
            request_level: false,
            allow_cross_server: false,
            allow_device: false,
            ..Self::epara()
        }
    }

    /// Galaxy: every GPU an edge device under one coordinator; MP across
    /// devices but no MT packing.
    pub fn galaxy() -> Self {
        PolicyConfig {
            name: "Galaxy",
            offload: OffloadMode::Centralized,
            placement: PlacementMode::LocalOnly,
            request_level: false,
            mt_enabled: false,
            allow_device: false,
            central_lat_base_ms: 2.0,
            central_lat_per_server_ms: 0.2,
            ..Self::epara()
        }
    }

    /// SERV-P: fully centralized placement+handling, NP-hard solver —
    /// Fig. 3e latency: >100 ms at 10 servers, >750 ms at 30+.
    pub fn servp() -> Self {
        PolicyConfig {
            name: "SERV-P",
            offload: OffloadMode::Centralized,
            placement: PlacementMode::LocalOnly,
            request_level: false,
            mt_enabled: false,
            bs_enabled: true,
            central_lat_base_ms: 10.0,
            central_lat_per_server_ms: 10.0,
            ..Self::epara()
        }
    }

    /// USHER: holistic interference-aware packing (MT strong), no
    /// request-level ops, centralized.
    pub fn usher() -> Self {
        PolicyConfig {
            name: "USHER",
            offload: OffloadMode::None,
            placement: PlacementMode::LocalOnly,
            request_level: false,
            allow_cross_server: false,
            allow_device: false,
            ..Self::epara()
        }
    }

    /// DeTransformer: block-parallel MP on edge devices; no MT, BS=1.
    pub fn detransformer() -> Self {
        PolicyConfig {
            name: "DeTransformer",
            offload: OffloadMode::None,
            placement: PlacementMode::LocalOnly,
            request_level: false,
            mt_enabled: false,
            bs_enabled: false,
            allow_device: false,
            central_lat_base_ms: 1.0,
            central_lat_per_server_ms: 0.1,
            ..Self::epara()
        }
    }

    /// The Fig. 10/14 comparison set.
    pub fn testbed_baselines() -> Vec<PolicyConfig> {
        vec![
            Self::epara(),
            Self::interedge(),
            Self::alpaserve(),
            Self::galaxy(),
            Self::servp(),
        ]
    }

    pub fn all_baselines() -> Vec<PolicyConfig> {
        vec![
            Self::epara(),
            Self::interedge(),
            Self::alpaserve(),
            Self::galaxy(),
            Self::servp(),
            Self::usher(),
            Self::detransformer(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{OperatorConfig, ServiceId, TaskCategory};

    fn dummy_alloc() -> Allocation {
        Allocation {
            service: ServiceId(0),
            category: TaskCategory::FrequencyMulti,
            ops: OperatorConfig {
                bs: 8,
                mt: 4,
                mp: MpKind::Tp(2),
                mf: 4,
                dp: 2,
            },
            expected_rate: 10.0,
            expected_latency_ms: 5.0,
            exclusive_gpu: false,
        }
    }

    #[test]
    fn interedge_strips_request_level_only() {
        let mut al = dummy_alloc();
        PolicyConfig::interedge().adjust_allocation(&mut al);
        assert_eq!(al.ops.mf, 1);
        assert_eq!(al.ops.dp, 1);
        assert_eq!(al.ops.bs, 8, "BS stays aligned with EPARA");
        assert_eq!(al.ops.mt, 4);
        assert_eq!(al.ops.mp, MpKind::Tp(2));
    }

    #[test]
    fn galaxy_strips_mt() {
        let mut al = dummy_alloc();
        PolicyConfig::galaxy().adjust_allocation(&mut al);
        assert_eq!(al.ops.mt, 1);
        assert!(al.exclusive_gpu, "no MT means whole-GPU deployments");
        assert_eq!(al.ops.mp, MpKind::Tp(2));
    }

    #[test]
    fn detransformer_strips_batching() {
        let mut al = dummy_alloc();
        PolicyConfig::detransformer().adjust_allocation(&mut al);
        assert_eq!(al.ops.bs, 1);
        assert_eq!(al.ops.mt, 1);
    }

    #[test]
    fn servp_latency_matches_fig3e() {
        let p = PolicyConfig::servp();
        assert!(p.central_latency_ms(10) > 100.0);
        assert!(p.central_latency_ms(30) < 750.0 * 1.2);
        assert!(p.central_latency_ms(80) > 750.0);
        assert_eq!(PolicyConfig::epara().central_latency_ms(1000), 0.0);
    }
}
