//! The live (wall-clock) serving path: EPARA's coordinator running real
//! PJRT inference on the AOT artifacts.
//!
//! Architecture (DESIGN.md): `PjRtClient` is not `Send`, and this testbed
//! exposes a single CPU core, so the execution model is one dedicated
//! **engine thread** owning the [`Engine`], fed by an mpsc job channel —
//! the same shape as the paper's per-GPU executor processes, with the
//! channel standing in for the MPS job queue.  The coordinator thread
//! implements the request-level operators on top:
//!
//! * **BS batching** — same-kind requests are coalesced up to the
//!   allocator's batch size within a batching window;
//! * **MF multi-frame** — frames of homogeneous video tasks are grouped
//!   into one batch entry (Eq. 5's inter-request count);
//! * **DP dispatch** — round-robin across lanes (per Fig. 1), which on a
//!   multi-GPU deployment would map lanes to GPU groups.
//!
//! Python never runs here: the binary serves from `artifacts/` alone.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::Engine;
use crate::util::stats::Summary;

/// A request the live coordinator can serve.
#[derive(Clone, Debug)]
pub enum ServeRequest {
    /// LLM chat: prompt (padded/truncated to prefill_len), new tokens.
    Chat { prompt: Vec<i32>, n_new: usize },
    /// One video frame (or image) for UNet segmentation, 64×64×3 flat.
    Segment { image: Vec<f32> },
    /// One image for CNN classification, 32×32×3 flat.
    Classify { image: Vec<f32> },
}

impl ServeRequest {
    fn kind(&self) -> usize {
        match self {
            ServeRequest::Chat { .. } => 0,
            ServeRequest::Segment { .. } => 1,
            ServeRequest::Classify { .. } => 2,
        }
    }
}

/// Jobs crossing into the engine thread.
enum Job {
    Generate {
        bs: usize,
        prompts: Vec<Vec<i32>>,
        n_new: usize,
        resp: mpsc::Sender<Result<Vec<Vec<i32>>>>,
    },
    Segment {
        bs: usize,
        images: Vec<f32>,
        resp: mpsc::Sender<Result<Vec<f32>>>,
    },
    Classify {
        bs: usize,
        images: Vec<f32>,
        resp: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Batching configuration (from the allocator's §4.1 search, pinned to
/// the batch sizes we compiled artifacts for).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Max batch for chat (must be one of the compiled llm bs variants).
    pub chat_bs: usize,
    pub chat_n_new: usize,
    /// Max batch for segmentation (compiled seg variants: 1/2/4).
    pub seg_bs: usize,
    /// Max batch for classification (compiled: 1/4/8).
    pub cls_bs: usize,
    /// Batch window: how long the batcher waits to fill a batch.
    pub window_ms: u64,
    /// DP lanes for frequency traffic (round-robin tag).
    pub dp_lanes: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            chat_bs: 4,
            chat_n_new: 8,
            seg_bs: 4,
            cls_bs: 8,
            window_ms: 5,
            dp_lanes: 2,
        }
    }
}

/// Serving statistics.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub served: usize,
    pub errors: usize,
    pub latency_ms: Summary,
    pub batch_sizes: Summary,
    pub wall_ms: f64,
    /// Requests per DP lane (round-robin balance check).
    pub lane_counts: Vec<usize>,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.served as f64 * 1000.0 / self.wall_ms
        }
    }

    pub fn report(&mut self, label: &str) -> String {
        format!(
            "{label}: served={} errors={} throughput={:.1} req/s \
             p50={:.1}ms p99={:.1}ms mean_batch={:.2} lanes={:?}",
            self.served,
            self.errors,
            self.throughput_rps(),
            self.latency_ms.p50(),
            self.latency_ms.p99(),
            self.batch_sizes.mean(),
            self.lane_counts,
        )
    }
}

/// Handle to the engine thread.
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
    join: Option<thread::JoinHandle<()>>,
}

impl EngineHandle {
    /// Spawn the engine thread; blocks until artifacts are loaded.
    pub fn spawn(artifacts: PathBuf) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name("epara-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&artifacts) {
                    Ok(e) => {
                        // §Perf: warm the serving-path executables so the
                        // first request doesn't pay PJRT compilation
                        // (measured: p50 5.4 s cold → ms-scale warm).
                        let warm = e.warm_serving_artifacts();
                        let _ = ready_tx.send(warm.map(|_| ()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Generate { bs, prompts, n_new, resp } => {
                            let _ = resp.send(engine.llm_generate(bs, &prompts, n_new));
                        }
                        Job::Segment { bs, images, resp } => {
                            let _ = resp.send(engine.segment(
                                bs,
                                &images,
                                &[bs, 64, 64, 3],
                            ));
                        }
                        Job::Classify { bs, images, resp } => {
                            let _ = resp.send(engine.classify(
                                bs,
                                &images,
                                &[bs, 32, 32, 3],
                            ));
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))??;
        Ok(EngineHandle { tx, join: Some(join) })
    }

    fn submit(&self, job: Job) {
        let _ = self.tx.send(job);
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The live coordinator.
pub struct Coordinator {
    engine: EngineHandle,
    pub cfg: BatchConfig,
    prefill_len: usize,
}

impl Coordinator {
    pub fn new(artifacts: PathBuf, cfg: BatchConfig) -> Result<Coordinator> {
        let engine = EngineHandle::spawn(artifacts)?;
        Ok(Coordinator { engine, cfg, prefill_len: 32 })
    }

    /// Pad/trim a prompt to the compiled prefill length.
    fn fit_prompt(&self, mut p: Vec<i32>) -> Vec<i32> {
        p.resize(self.prefill_len, 0);
        p
    }

    /// Largest compiled batch size ≤ n for each kind.
    fn feasible_bs(kind: usize, n: usize, cfg: &BatchConfig) -> usize {
        let candidates: &[usize] = match kind {
            0 => &[4, 2, 1],
            1 => &[4, 2, 1],
            _ => &[8, 4, 1],
        };
        let cap = match kind {
            0 => cfg.chat_bs,
            1 => cfg.seg_bs,
            _ => cfg.cls_bs,
        };
        *candidates
            .iter()
            .find(|&&c| c <= n.min(cap))
            .unwrap_or(&1)
    }

    /// Serve a timed workload: (offset_ms, request) pairs, offsets
    /// relative to start.  Runs BS batching with the configured window
    /// and DP round-robin tagging; blocks until all requests finish.
    pub fn serve(&self, workload: Vec<(u64, ServeRequest)>) -> Result<ServeStats> {
        let mut stats = ServeStats {
            lane_counts: vec![0; self.cfg.dp_lanes.max(1)],
            ..Default::default()
        };
        let start = Instant::now();
        let mut pending: Vec<(u64, ServeRequest)> = workload;
        pending.sort_by_key(|(t, _)| *t);
        let mut queue: VecDeque<(Instant, ServeRequest)> = VecDeque::new();
        let mut idx = 0usize;
        let mut lane = 0usize;

        while idx < pending.len() || !queue.is_empty() {
            // admit arrivals whose time has come
            let now = start.elapsed().as_millis() as u64;
            while idx < pending.len() && pending[idx].0 <= now {
                queue.push_back((Instant::now(), pending[idx].1.clone()));
                idx += 1;
            }
            if queue.is_empty() {
                if idx < pending.len() {
                    let wait = pending[idx].0.saturating_sub(now);
                    thread::sleep(Duration::from_millis(wait.min(5)));
                }
                continue;
            }

            // batch window: wait briefly for same-kind arrivals
            let kind = queue.front().unwrap().1.kind();
            let window_end = Instant::now() + Duration::from_millis(self.cfg.window_ms);
            loop {
                let now = start.elapsed().as_millis() as u64;
                while idx < pending.len() && pending[idx].0 <= now {
                    queue.push_back((Instant::now(), pending[idx].1.clone()));
                    idx += 1;
                }
                let same: usize =
                    queue.iter().filter(|(_, r)| r.kind() == kind).count();
                let cap = Self::feasible_bs(kind, usize::MAX, &self.cfg);
                if same >= cap || Instant::now() >= window_end {
                    break;
                }
                thread::sleep(Duration::from_micros(300));
            }

            // drain up to bs same-kind requests (front-kind priority)
            let avail = queue.iter().filter(|(_, r)| r.kind() == kind).count();
            let bs = Self::feasible_bs(kind, avail, &self.cfg);
            let mut batch: Vec<(Instant, ServeRequest)> = Vec::with_capacity(bs);
            let mut i = 0;
            while i < queue.len() && batch.len() < bs {
                if queue[i].1.kind() == kind {
                    batch.push(queue.remove(i).unwrap());
                } else {
                    i += 1;
                }
            }
            stats.batch_sizes.add(batch.len() as f64);
            let n_lanes = stats.lane_counts.len();
            stats.lane_counts[lane % n_lanes] += batch.len();
            lane += 1;

            self.execute_batch(kind, batch, &mut stats)?;
        }
        stats.wall_ms = start.elapsed().as_secs_f64() * 1000.0;
        Ok(stats)
    }

    fn execute_batch(
        &self,
        kind: usize,
        batch: Vec<(Instant, ServeRequest)>,
        stats: &mut ServeStats,
    ) -> Result<()> {
        let bs = batch.len();
        match kind {
            0 => {
                let (tx, rx) = mpsc::channel();
                let prompts: Vec<Vec<i32>> = batch
                    .iter()
                    .map(|(_, r)| match r {
                        ServeRequest::Chat { prompt, .. } => {
                            self.fit_prompt(prompt.clone())
                        }
                        _ => unreachable!(),
                    })
                    .collect();
                let n_new = self.cfg.chat_n_new;
                self.engine.submit(Job::Generate { bs, prompts, n_new, resp: tx });
                match rx.recv() {
                    Ok(Ok(_tokens)) => stats.served += bs,
                    _ => stats.errors += bs,
                }
            }
            1 => {
                let (tx, rx) = mpsc::channel();
                let images: Vec<f32> = batch
                    .iter()
                    .flat_map(|(_, r)| match r {
                        ServeRequest::Segment { image } => image.clone(),
                        _ => unreachable!(),
                    })
                    .collect();
                self.engine.submit(Job::Segment { bs, images, resp: tx });
                match rx.recv() {
                    Ok(Ok(_)) => stats.served += bs,
                    _ => stats.errors += bs,
                }
            }
            _ => {
                let (tx, rx) = mpsc::channel();
                let images: Vec<f32> = batch
                    .iter()
                    .flat_map(|(_, r)| match r {
                        ServeRequest::Classify { image } => image.clone(),
                        _ => unreachable!(),
                    })
                    .collect();
                self.engine.submit(Job::Classify { bs, images, resp: tx });
                match rx.recv() {
                    Ok(Ok(_)) => stats.served += bs,
                    _ => stats.errors += bs,
                }
            }
        }
        for (t0, _) in &batch {
            stats.latency_ms.add(t0.elapsed().as_secs_f64() * 1000.0);
        }
        Ok(())
    }
}

/// Build a deterministic synthetic serving workload (used by the
/// quickstart example and `epara serve`).
pub fn synthetic_workload(n: usize, rps: f64, seed: u64) -> Vec<(u64, ServeRequest)> {
    let mut rng = crate::util::Rng::new(seed);
    let mut t = 0f64;
    (0..n)
        .map(|i| {
            t += rng.exp(rps / 1000.0);
            let req = match i % 3 {
                0 => ServeRequest::Chat {
                    prompt: (0..32).map(|j| ((i + j) % 512) as i32).collect(),
                    n_new: 8,
                },
                1 => ServeRequest::Segment {
                    image: (0..64 * 64 * 3)
                        .map(|j| ((i * 31 + j) % 255) as f32 / 255.0)
                        .collect(),
                },
                _ => ServeRequest::Classify {
                    image: (0..32 * 32 * 3)
                        .map(|j| ((i * 17 + j) % 255) as f32 / 255.0)
                        .collect(),
                },
            };
            (t as u64, req)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasible_bs_picks_compiled_variants() {
        let cfg = BatchConfig::default();
        assert_eq!(Coordinator::feasible_bs(0, 1, &cfg), 1);
        assert_eq!(Coordinator::feasible_bs(0, 3, &cfg), 2);
        assert_eq!(Coordinator::feasible_bs(0, 7, &cfg), 4);
        assert_eq!(Coordinator::feasible_bs(2, 100, &cfg), 8);
        assert_eq!(Coordinator::feasible_bs(1, 2, &cfg), 2);
    }

    #[test]
    fn synthetic_workload_deterministic() {
        let a = synthetic_workload(50, 100.0, 3);
        let b = synthetic_workload(50, 100.0, 3);
        assert_eq!(a.len(), b.len());
        for ((t1, _), (t2, _)) in a.iter().zip(&b) {
            assert_eq!(t1, t2);
        }
        // arrival times are non-decreasing
        for w in a.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }
}
