//! Core vocabulary of the paper: tasks, categories, SLOs, operators.
//!
//! §2.1: "When a user *request* specifies a *service* as its target, such
//! combination constitutes a *task*."  Everything else in the crate speaks
//! these types.

/// Logical AI service (a model deployment), e.g. "llama3-8b-chat".
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ServiceId(pub u32);

/// Edge server (one node of the edge cloud).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ServerId(pub u32);

/// One GPU within a server.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct GpuId {
    pub server: ServerId,
    pub index: u8,
}

/// Registered edge device (Raspberry Pi / Jetson / FPGA card).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct DeviceId(pub u32);

/// A user request instance.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct RequestId(pub u64);

/// §3.1: sensitivity axis of the task taxonomy.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Sensitivity {
    /// Non-continuous requests; latency is the sole SLO (chat, images).
    Latency,
    /// Continuous/periodic requests; rate (fps / tokens-per-sec) is the
    /// binding SLO, latency a baseline expectation (video, HCI).
    Frequency,
}

/// §3.1: resource axis — does the service fit one GPU?
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum GpuDemand {
    /// ≤ 1 GPU: packing operators (BS, MT, MF) suffice.
    Single,
    /// > 1 GPU: parallelism operators (MP, DP) required.
    Multi,
}

/// The four task categories of Fig. 5.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum TaskCategory {
    LatencySingle,
    LatencyMulti,
    FrequencySingle,
    FrequencyMulti,
}

impl TaskCategory {
    pub fn of(sens: Sensitivity, demand: GpuDemand) -> Self {
        match (sens, demand) {
            (Sensitivity::Latency, GpuDemand::Single) => TaskCategory::LatencySingle,
            (Sensitivity::Latency, GpuDemand::Multi) => TaskCategory::LatencyMulti,
            (Sensitivity::Frequency, GpuDemand::Single) => TaskCategory::FrequencySingle,
            (Sensitivity::Frequency, GpuDemand::Multi) => TaskCategory::FrequencyMulti,
        }
    }

    pub fn sensitivity(self) -> Sensitivity {
        match self {
            TaskCategory::LatencySingle | TaskCategory::LatencyMulti => Sensitivity::Latency,
            _ => Sensitivity::Frequency,
        }
    }

    pub fn demand(self) -> GpuDemand {
        match self {
            TaskCategory::LatencySingle | TaskCategory::FrequencySingle => GpuDemand::Single,
            _ => GpuDemand::Multi,
        }
    }

    pub const ALL: [TaskCategory; 4] = [
        TaskCategory::LatencySingle,
        TaskCategory::LatencyMulti,
        TaskCategory::FrequencySingle,
        TaskCategory::FrequencyMulti,
    ];
}

/// Service-level objective.
///
/// Latency-sensitive tasks: complete within `latency_ms`.
/// Frequency-sensitive tasks: additionally sustain `min_rate` (fps or
/// tokens/s); §3.3 grants fractional credit — achieving 30 of a 60 fps
/// target on a 120-frame request satisfies 120·30/60 = 60 requests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slo {
    pub latency_ms: f64,
    pub min_rate: Option<f64>,
}

impl Slo {
    pub fn latency(ms: f64) -> Self {
        Slo { latency_ms: ms, min_rate: None }
    }

    pub fn rate(ms: f64, rate: f64) -> Self {
        Slo { latency_ms: ms, min_rate: Some(rate) }
    }
}

/// §3.1: model parallelism configuration (the MP operator).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum MpKind {
    /// Single GPU, no model parallelism.
    None,
    /// Tensor parallelism over k GPUs.
    Tp(u8),
    /// Pipeline parallelism over k stages.
    Pp(u8),
    /// Combined TP×PP (e.g. TP2+PP2 for Qwen2.5-32B in §4.3).
    TpPp(u8, u8),
}

impl MpKind {
    /// Number of GPUs one replica occupies.
    pub fn gpus(self) -> u32 {
        match self {
            MpKind::None => 1,
            MpKind::Tp(k) | MpKind::Pp(k) => k as u32,
            MpKind::TpPp(t, p) => t as u32 * p as u32,
        }
    }
}

/// §3.1: the full operator assignment the allocator produces per service.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatorConfig {
    /// Batching: requests of the same service grouped per execution.
    pub bs: u32,
    /// Multi-task: replicas of this service packed on one GPU (MPS-style).
    pub mt: u32,
    /// Model parallelism (TP/PP) across GPUs.
    pub mp: MpKind,
    /// Multi-frame: frames of homogeneous tasks grouped in one batch
    /// (request-level; 1 = disabled).
    pub mf: u32,
    /// Data parallelism: DP group count for round-robin frame dispatch
    /// (request-level; 1 = disabled).
    pub dp: u32,
}

impl Default for OperatorConfig {
    fn default() -> Self {
        OperatorConfig { bs: 1, mt: 1, mp: MpKind::None, mf: 1, dp: 1 }
    }
}

impl OperatorConfig {
    /// GPUs required by one full deployment of this config (Eq. 4's DP
    /// groups × the MP footprint).
    pub fn gpus(&self) -> u32 {
        self.dp * self.mp.gpus()
    }

    /// §4.1 Eq. (5): inter-request count = floor(BS / max(MF, 1)).
    pub fn inter_request_count(&self) -> u32 {
        (self.bs / self.mf.max(1)).max(1)
    }
}

/// Static description of a deployable service.
#[derive(Clone, Debug)]
pub struct ServiceSpec {
    pub id: ServiceId,
    pub name: String,
    pub sensitivity: Sensitivity,
    /// VRAM one replica needs at MP=None (MB) — `b_l` in Eq. 3.
    pub vram_mb: f64,
    /// Fraction of one GPU's compute an MPS slice consumes — `a_l` in Eq. 3.
    pub compute_slice: f64,
    /// Time to transfer + load the model onto a GPU (Fig. 3f).
    pub model_load_ms: f64,
    /// Request payload (KB) crossing the network on offload.
    pub payload_kb: f64,
    /// SLO for this service's tasks.
    pub slo: Slo,
    /// Frames per frequency-sensitive request (1 for latency tasks).
    pub frames_per_request: u32,
}

impl ServiceSpec {
    /// Whether one replica fits a single GPU of `gpu_vram_mb`.
    pub fn fits_single_gpu(&self, gpu_vram_mb: f64) -> bool {
        self.vram_mb <= gpu_vram_mb
    }

    pub fn demand(&self, gpu_vram_mb: f64) -> GpuDemand {
        if self.fits_single_gpu(gpu_vram_mb) {
            GpuDemand::Single
        } else {
            GpuDemand::Multi
        }
    }

    pub fn category(&self, gpu_vram_mb: f64) -> TaskCategory {
        TaskCategory::of(self.sensitivity, self.demand(gpu_vram_mb))
    }
}

/// A user request (the paper's r / r_{tln}).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub service: ServiceId,
    /// Arrival time at the first edge server (ms, virtual time).
    pub arrival_ms: f64,
    /// Server the user contacted.
    pub origin: ServerId,
    /// Frames carried (frequency tasks; 1 otherwise).
    pub frames: u32,
    /// Offload hop trail (§3.2 "offloading paths": loop prevention).
    pub path: Vec<ServerId>,
    /// Offload count so far (bounded by max_offloads, §4.1).
    pub offloads: u32,
}

/// Terminal outcome of request handling (Fig. 6's four exits).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Outcome {
    /// Fully processed within SLO; completion latency in ms.
    Completed { latency_ms: f64 },
    /// Frequency task partially satisfied: `satisfied` of `total` frames
    /// met the rate SLO (fractional credit, §3.3).
    Partial { satisfied: f64, total: u32 },
    /// SLO violation — dropped.
    Timeout,
    /// Max offload count reached.
    OffloadExceeded,
    /// No feasible server (Fig. 6 "resource insufficiency").
    ResourceInsufficient,
}

impl Outcome {
    /// Goodput credit this outcome contributes (satisfied request count).
    pub fn credit(&self) -> f64 {
        match self {
            Outcome::Completed { .. } => 1.0,
            Outcome::Partial { satisfied, total } => {
                if *total == 0 { 0.0 } else { satisfied / *total as f64 }
            }
            _ => 0.0,
        }
    }

    pub fn is_success(&self) -> bool {
        self.credit() > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_axes() {
        assert_eq!(
            TaskCategory::of(Sensitivity::Frequency, GpuDemand::Multi),
            TaskCategory::FrequencyMulti
        );
        for c in TaskCategory::ALL {
            assert_eq!(TaskCategory::of(c.sensitivity(), c.demand()), c);
        }
    }

    #[test]
    fn mp_gpu_counts() {
        assert_eq!(MpKind::None.gpus(), 1);
        assert_eq!(MpKind::Tp(2).gpus(), 2);
        assert_eq!(MpKind::Pp(4).gpus(), 4);
        assert_eq!(MpKind::TpPp(2, 2).gpus(), 4);
    }

    #[test]
    fn operator_footprint() {
        let cfg = OperatorConfig { dp: 2, mp: MpKind::Tp(2), ..Default::default() };
        assert_eq!(cfg.gpus(), 4);
    }

    #[test]
    fn inter_request_count_eq5() {
        // Eq. (5): floor(BS / max(MF))
        let cfg = OperatorConfig { bs: 8, mf: 4, ..Default::default() };
        assert_eq!(cfg.inter_request_count(), 2);
        let cfg = OperatorConfig { bs: 4, mf: 8, ..Default::default() };
        assert_eq!(cfg.inter_request_count(), 1); // clamped to >= 1
    }

    #[test]
    fn outcome_credit() {
        assert_eq!(Outcome::Completed { latency_ms: 1.0 }.credit(), 1.0);
        let p = Outcome::Partial { satisfied: 60.0, total: 120 };
        assert!((p.credit() - 0.5).abs() < 1e-12);
        assert_eq!(Outcome::Timeout.credit(), 0.0);
        assert!(!Outcome::ResourceInsufficient.is_success());
    }

    #[test]
    fn service_demand_vs_vram() {
        let spec = ServiceSpec {
            id: ServiceId(0),
            name: "llama3-70b".into(),
            sensitivity: Sensitivity::Latency,
            vram_mb: 40_000.0,
            compute_slice: 1.0,
            model_load_ms: 20_000.0,
            payload_kb: 8.0,
            slo: Slo::latency(4000.0),
            frames_per_request: 1,
        };
        assert_eq!(spec.demand(16_000.0), GpuDemand::Multi);
        assert_eq!(spec.category(16_000.0), TaskCategory::LatencyMulti);
        assert_eq!(spec.demand(80_000.0), GpuDemand::Single);
    }
}
