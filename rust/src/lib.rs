//! # EPARA — Parallelizing Categorized AI Inference in Edge Clouds
//!
//! Reproduction of the EPARA paper (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack.  This crate is Layer 3: the paper's entire
//! coordination contribution plus every substrate it depends on.
//!
//! Architecture (see `DESIGN.md` for the full inventory):
//!
//! * [`core`] — task/request/service vocabulary: the four task categories
//!   (§3.1), SLOs, the five allocation operators (BS/MT/MP/MF/DP).
//! * [`allocator`] — task-categorized parallelism allocator (§3.1, §4.1).
//! * [`handler`] — distributed request handler with probabilistic
//!   idle-goodput offloading (§3.2, Eq. 1).
//! * [`placement`] — state-aware submodular service placement
//!   (§3.3, Algorithms 1–2, the 1/(1+P) bound of Eq. 3 / Appendix A).
//! * [`predict`] — online latency models (EWMA + Robbins–Monro quantile)
//!   and a Holt arrival-rate forecaster feeding predictive admission on
//!   the gateway and proactive placement rounds in the sim (off by
//!   default; disabled it reproduces the prior engine bit-for-bit).
//! * [`sync`] — ring-reduce information synchronization (§3.4).
//! * [`modelcache`] — per-server weight caches with family-aware partial
//!   loads: deterministic LRU over backbone/delta byte footprints, so
//!   recovery and re-placement pay only for bytes not already resident
//!   (capacity 0 disables it and reproduces flat Fig. 3f loads exactly).
//! * [`cluster`], [`profile`], [`workload`] — the edge-cloud substrate:
//!   servers/GPUs/devices/links, offline profiling tables, and the
//!   Azure-trace-shaped workload generator.
//! * [`sim`] — the event-driven simulator of §5.2 (virtual time, goodput
//!   accounting with fractional frequency credit).
//! * [`server`] — the network serving gateway: socket-facing HTTP/1.1
//!   request path with category-aware admission, BS batching windows,
//!   SLO-budget load shedding, Prometheus metrics, and a load generator
//!   (`epara gateway` / `epara loadgen`).  Execution is pluggable: the
//!   default backend replays `profile` tables on wall-clock time; the
//!   `pjrt` feature bridges to the coordinator.
//! * [`scenario`] — deterministic churn/fault/surge scenario engine:
//!   declarative JSON timelines (`server_fail`, `device_leave`,
//!   `rps_surge`, …) executed against the sim (bit-exact, golden-pinned)
//!   and the live gateway (time-scaled) through one backend trait, with
//!   per-phase goodput/recovery reports (`epara scenario run|list`).
//! * [`baselines`] — InterEdge, AlpaServe, Galaxy, SERV-P, USHER,
//!   DeTransformer comparison policies behind one trait.
//! * `runtime` — PJRT CPU engine loading the AOT artifacts
//!   (`artifacts/*.hlo.txt`); TP2 combine and PP2 piping live here.
//!   Gated on the `pjrt` cargo feature (off by default — CI cannot load a
//!   PJRT plugin; see DESIGN.md for the feature matrix).
//! * `coordinator` — the real (wall-clock) serving path built on the
//!   runtime: per-GPU workers, BS/MF batching, DP dispatch.  Also gated
//!   on `pjrt`.
//! * [`util`], [`configjson`], [`metrics`] — in-crate substrates required
//!   by the offline registry (RNG, stats, property-test harness, JSON,
//!   metrics registry).
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); this
//! crate is self-contained afterwards — nothing on the request path ever
//! calls Python.

pub mod allocator;
pub mod baselines;
pub mod cluster;
pub mod configjson;
#[cfg(feature = "pjrt")]
pub mod coordinator;
pub mod core;
pub mod handler;
pub mod metrics;
pub mod modelcache;
pub mod placement;
pub mod predict;
pub mod profile;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod sim;
pub mod sync;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Locate the `artifacts/` directory.
///
/// Single source of truth for the whole crate (the CLI feeds its
/// `--artifacts` flag through `explicit`): an explicit non-empty override
/// wins, then `$EPARA_ARTIFACTS`, then `./artifacts`.
pub fn artifacts_dir_from(explicit: Option<&str>) -> std::path::PathBuf {
    match explicit {
        Some(dir) if !dir.is_empty() => std::path::PathBuf::from(dir),
        _ => std::env::var_os("EPARA_ARTIFACTS")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from("artifacts")),
    }
}

/// Locate the `artifacts/` directory: `$EPARA_ARTIFACTS` or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    artifacts_dir_from(None)
}
