//! Goodput and resource accounting shared by the simulator, the live
//! coordinator, and the benches.
//!
//! Goodput follows §3.3: latency tasks count 1 when completed in-SLO;
//! frequency tasks earn fractional credit (achieved/target rate, e.g.
//! 120 frames × 30/60 fps = 60 satisfied requests).  Resource metrics
//! reproduce Fig. 13 (compute occupancy + VRAM utilization).

use std::collections::HashMap;

use crate::core::{Outcome, ServiceId};
use crate::util::stats::Summary;

/// Aggregated run metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Total requests observed.
    pub offered: u64,
    /// Goodput credit earned (fractional; §3.3 accounting).
    pub satisfied: f64,
    /// Outcome counters.
    pub completed: u64,
    pub partial: u64,
    pub timeout: u64,
    pub offload_exceeded: u64,
    pub resource_insufficient: u64,
    /// Completion latencies (ms) of successful requests.
    pub latency: Summary,
    /// Offload hops per handled request (Fig. 17e).
    pub offload_counts: Summary,
    /// Per-service goodput credit.
    pub per_service: HashMap<ServiceId, f64>,
    /// Virtual duration covered (ms).
    pub duration_ms: f64,
    /// GPU busy-time integral (gpu·ms) and capacity (gpu·ms).
    pub gpu_busy_ms: f64,
    pub gpu_capacity_ms: f64,
    /// VRAM in use (MB·ms integral) and capacity.
    pub vram_used_mb_ms: f64,
    pub vram_capacity_mb_ms: f64,
    /// Whether the weight-cache subsystem was enabled for this run.
    /// Gates the cache section of [`Metrics::fingerprint`] so capacity-0
    /// runs reproduce the pre-cache fingerprints byte-for-byte.
    pub cache_enabled: bool,
    /// Weight-cache admissions by outcome (modelcache subsystem).
    pub cache_hits: u64,
    pub cache_partial: u64,
    pub cache_misses: u64,
    /// Bytes actually transferred for model loads / saved by residency.
    pub cache_bytes_loaded_mb: f64,
    pub cache_bytes_saved_mb: f64,
    /// Total model-load delay paid across all deployment spawns (ms).
    /// Accumulated on the cache-disabled path too (flat loads), so
    /// cache-aware vs cache-blind runs are directly comparable — but it
    /// is NOT part of the base fingerprint.
    pub model_load_ms_total: f64,
    /// Whether the request-lifecycle resilience layer was enabled.
    /// Gates the resilience fingerprint section exactly like
    /// `cache_enabled` gates the cache section.
    pub resilience_enabled: bool,
    /// Executor attempts re-tried under the retry budget.
    pub retries: u64,
    /// Requests dropped by deadline-budget checks before/while running.
    pub deadline_expired: u64,
    /// Circuit-breaker transitions into Open.
    pub breaker_trips: u64,
    /// Requests short-circuited (fast-failed) by an open breaker.
    pub breaker_short_circuits: u64,
    /// Whether the online prediction layer was enabled (and could run —
    /// the sim also requires periodic re-placement).  Gates the predict
    /// fingerprint section exactly like the cache/resilience switches.
    pub predict_enabled: bool,
    /// Forecast-triggered early placement rounds.
    pub pred_early_rounds: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one terminal request outcome.
    pub fn record(&mut self, service: ServiceId, outcome: &Outcome, offloads: u32) {
        self.offered += 1;
        self.offload_counts.add(offloads as f64);
        let credit = outcome.credit();
        self.satisfied += credit;
        *self.per_service.entry(service).or_insert(0.0) += credit;
        match outcome {
            Outcome::Completed { latency_ms } => {
                self.completed += 1;
                self.latency.add(*latency_ms);
            }
            Outcome::Partial { .. } => self.partial += 1,
            Outcome::Timeout => self.timeout += 1,
            Outcome::OffloadExceeded => self.offload_exceeded += 1,
            Outcome::ResourceInsufficient => self.resource_insufficient += 1,
        }
    }

    /// Goodput in satisfied requests per second.
    pub fn goodput_rps(&self) -> f64 {
        if self.duration_ms <= 0.0 {
            0.0
        } else {
            self.satisfied * 1000.0 / self.duration_ms
        }
    }

    /// Fraction of offered requests satisfied (fractional credit).
    pub fn satisfaction_ratio(&self) -> f64 {
        if self.offered == 0 {
            1.0
        } else {
            self.satisfied / self.offered as f64
        }
    }

    /// Fig. 13's compute occupancy (clamped: the batch-window share model
    /// can slightly overcount under cross-server 1.25× service times).
    pub fn gpu_utilization(&self) -> f64 {
        if self.gpu_capacity_ms <= 0.0 {
            0.0
        } else {
            (self.gpu_busy_ms / self.gpu_capacity_ms).min(1.0)
        }
    }

    /// Fig. 13's VRAM utilization.
    pub fn vram_utilization(&self) -> f64 {
        if self.vram_capacity_mb_ms <= 0.0 {
            0.0
        } else {
            self.vram_used_mb_ms / self.vram_capacity_mb_ms
        }
    }

    /// Mean offload hops (Fig. 17e).
    pub fn mean_offloads(&self) -> f64 {
        self.offload_counts.mean()
    }

    /// Canonical bit-exact fingerprint of a run: every outcome counter plus
    /// the f64 accumulators rendered as raw bits, per-service credits
    /// sorted by id.  The determinism golden test compares this across
    /// engine refactors to prove data-structure swaps are
    /// semantics-preserving — any drift in goodput accounting, outcome
    /// counts, or per-service credit flips a hex digit.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write as _;
        let mut per: Vec<(u32, u64)> = self
            .per_service
            .iter()
            .map(|(s, v)| (s.0, v.to_bits()))
            .collect();
        per.sort_unstable();
        let mut out = format!(
            "offered={} satisfied={:016x} completed={} partial={} timeout={} \
             offload_exceeded={} resource_insufficient={} gpu_busy={:016x}",
            self.offered,
            self.satisfied.to_bits(),
            self.completed,
            self.partial,
            self.timeout,
            self.offload_exceeded,
            self.resource_insufficient,
            self.gpu_busy_ms.to_bits(),
        );
        for (s, v) in per {
            let _ = write!(out, " svc{s}={v:016x}");
        }
        // Cache section only when the subsystem ran: a disabled cache
        // must reproduce the historical fingerprint byte-for-byte.
        if self.cache_enabled {
            let _ = write!(
                out,
                " cache[h={} p={} m={} loaded={:016x} saved={:016x} \
                 loadms={:016x}]",
                self.cache_hits,
                self.cache_partial,
                self.cache_misses,
                self.cache_bytes_loaded_mb.to_bits(),
                self.cache_bytes_saved_mb.to_bits(),
                self.model_load_ms_total.to_bits(),
            );
        }
        // Resilience section, same stance: disabled runs reproduce the
        // pre-resilience fingerprint byte-for-byte.
        if self.resilience_enabled {
            let _ = write!(
                out,
                " res[r={} x={} bt={} bs={}]",
                self.retries,
                self.deadline_expired,
                self.breaker_trips,
                self.breaker_short_circuits,
            );
        }
        // Predict section, same stance: disabled runs reproduce the
        // pre-prediction fingerprint byte-for-byte.
        if self.predict_enabled {
            let _ = write!(out, " pred[er={}]", self.pred_early_rounds);
        }
        out
    }

    /// One-line report for benches.
    pub fn report(&mut self, label: &str) -> String {
        format!(
            "{label}: goodput={:.2} req/s satisfied={:.1}/{} (ratio {:.3}) \
             p50={:.1}ms p99={:.1}ms offloads={:.2} util(gpu {:.1}%, vram {:.1}%)",
            self.goodput_rps(),
            self.satisfied,
            self.offered,
            self.satisfaction_ratio(),
            self.latency.p50(),
            self.latency.p99(),
            self.mean_offloads(),
            self.gpu_utilization() * 100.0,
            self.vram_utilization() * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractional_credit_accounting() {
        let mut m = Metrics::new();
        m.duration_ms = 1000.0;
        m.record(ServiceId(0), &Outcome::Completed { latency_ms: 5.0 }, 0);
        m.record(ServiceId(0), &Outcome::Partial { satisfied: 60.0, total: 120 }, 1);
        m.record(ServiceId(1), &Outcome::Timeout, 2);
        assert_eq!(m.offered, 3);
        assert!((m.satisfied - 1.5).abs() < 1e-12);
        assert!((m.goodput_rps() - 1.5).abs() < 1e-12);
        assert!((m.per_service[&ServiceId(0)] - 1.5).abs() < 1e-12);
        assert!((m.mean_offloads() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_ratios() {
        let mut m = Metrics::new();
        m.gpu_busy_ms = 950.0;
        m.gpu_capacity_ms = 1000.0;
        m.vram_used_mb_ms = 98.0;
        m.vram_capacity_mb_ms = 100.0;
        assert!((m.gpu_utilization() - 0.95).abs() < 1e-12);
        assert!((m.vram_utilization() - 0.98).abs() < 1e-12);
    }

    #[test]
    fn fingerprint_is_order_independent_and_bit_exact() {
        let build = |order: &[u32]| {
            let mut m = Metrics::new();
            for &s in order {
                m.record(ServiceId(s), &Outcome::Completed { latency_ms: s as f64 }, 0);
            }
            m
        };
        let a = build(&[3, 1, 2]);
        let b = build(&[2, 3, 1]);
        // same multiset of outcomes → same fingerprint (per-service entries
        // are sorted, not hash-ordered)
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = build(&[3, 1, 2]);
        c.record(ServiceId(1), &Outcome::Partial { satisfied: 1.0, total: 3 }, 1);
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert!(a.fingerprint().contains("svc1="));
    }

    #[test]
    fn cache_section_only_fingerprints_when_enabled() {
        let mut m = Metrics::new();
        m.record(ServiceId(0), &Outcome::Completed { latency_ms: 1.0 }, 0);
        m.cache_hits = 3;
        m.cache_misses = 1;
        m.model_load_ms_total = 550.0;
        // disabled: counters may exist (blind-run bookkeeping) but the
        // fingerprint must stay byte-identical to a cache-less build
        let disabled = m.fingerprint();
        assert!(!disabled.contains("cache["), "{disabled}");
        m.cache_enabled = true;
        let enabled = m.fingerprint();
        assert!(enabled.contains("cache[h=3 p=0 m=1"), "{enabled}");
        assert!(enabled.starts_with(&disabled));
    }

    #[test]
    fn resilience_section_only_fingerprints_when_enabled() {
        let mut m = Metrics::new();
        m.record(ServiceId(0), &Outcome::Completed { latency_ms: 1.0 }, 0);
        m.retries = 5;
        m.breaker_trips = 1;
        m.deadline_expired = 2;
        let disabled = m.fingerprint();
        assert!(!disabled.contains("res["), "{disabled}");
        m.resilience_enabled = true;
        let enabled = m.fingerprint();
        assert!(enabled.contains("res[r=5 x=2 bt=1 bs=0]"), "{enabled}");
        assert!(enabled.starts_with(&disabled));
        // the cache and resilience sections compose in a fixed order
        m.cache_enabled = true;
        let both = m.fingerprint();
        let cache_at = both.find("cache[").expect("cache section");
        let res_at = both.find("res[").expect("res section");
        assert!(cache_at < res_at);
    }

    #[test]
    fn predict_section_only_fingerprints_when_enabled() {
        let mut m = Metrics::new();
        m.record(ServiceId(0), &Outcome::Completed { latency_ms: 1.0 }, 0);
        m.pred_early_rounds = 2;
        let disabled = m.fingerprint();
        assert!(!disabled.contains("pred["), "{disabled}");
        m.predict_enabled = true;
        let enabled = m.fingerprint();
        assert!(enabled.contains("pred[er=2]"), "{enabled}");
        assert!(enabled.starts_with(&disabled));
        // fixed composition order: cache, then resilience, then predict
        m.cache_enabled = true;
        m.resilience_enabled = true;
        let all = m.fingerprint();
        let cache_at = all.find("cache[").expect("cache section");
        let res_at = all.find("res[").expect("res section");
        let pred_at = all.find("pred[").expect("pred section");
        assert!(cache_at < res_at && res_at < pred_at);
    }

    #[test]
    fn empty_metrics_are_sane() {
        let mut m = Metrics::new();
        assert_eq!(m.goodput_rps(), 0.0);
        assert_eq!(m.satisfaction_ratio(), 1.0);
        assert!(!m.report("x").is_empty());
    }
}
