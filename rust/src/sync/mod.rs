//! Information synchronization (§3.4): ring-reduce state exchange with
//! three temporal granularities, grouping for scale (Fig. 18a), and the
//! §5.3.3 fault model (silent corruption self-heal, detected-loss bypass).
//!
//! Servers form a ring; each round every server exchanges its request
//! arrival/processing status and its cached system-wide state with both
//! neighbours (ring-reduce/all-gather), so a round moves ~2× the total
//! state per node pipelined over N−1 hops.  The handler never sees fresh
//! truth — it sees state `t_n` old (Eq. 1's ẗ window), and prolonged sync
//! delays increase offload misses (Fig. 17e).

use crate::core::ServerId;

/// Sync protocol configuration.
#[derive(Clone, Copy, Debug)]
pub struct SyncConfig {
    /// Gap between sync rounds (ms).
    pub interval_ms: f64,
    /// Link bandwidth used by the protocol (Mb/s).
    pub bandwidth_mbps: f64,
    /// Per-server state record size (KB): arrivals, per-service goodput,
    /// queue depths.
    pub state_kb: f64,
    /// Per-hop forwarding latency (ms).
    pub hop_latency_ms: f64,
    /// Per-hop processing cost (ms).
    pub proc_ms: f64,
    /// Optional grouping: ring within groups of this size, plus a second
    /// level across group leaders via the messager (Fig. 18a's fix).
    pub group_size: Option<usize>,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig {
            interval_ms: 1000.0,
            bandwidth_mbps: 500.0,
            state_kb: 2.0,
            hop_latency_ms: 0.15,
            proc_ms: 0.02,
            group_size: None,
        }
    }
}

impl SyncConfig {
    /// Delay for one complete ring round over `n` members: pipelined
    /// all-gather (2·n·state over the link) plus hop latency/processing.
    pub fn ring_delay_ms(&self, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let data_ms = 2.0 * n as f64 * self.state_kb * 8.0 / self.bandwidth_mbps;
        let hops = (n - 1) as f64;
        data_ms + hops * (self.hop_latency_ms + self.proc_ms)
    }

    /// Full-cloud sync delay with optional two-level grouping.
    pub fn full_sync_delay_ms(&self, n: usize) -> f64 {
        match self.group_size {
            None => self.ring_delay_ms(n),
            Some(g) if g >= n => self.ring_delay_ms(n),
            Some(g) => {
                let groups = n.div_ceil(g);
                // group-local ring + leader ring (state aggregated per group)
                self.ring_delay_ms(g) + self.ring_delay_ms(groups)
            }
        }
    }
}

/// Per-server fault state.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Fault {
    Healthy,
    /// Silent data error until the given virtual time: cached state about
    /// this server is wrong by `factor` (undetected; self-heals at the
    /// next sync round after `until_ms`).
    SilentError { until_ms: f64, factor: f64 },
    /// Detected unresponsive: bypassed by the ring, excluded from
    /// placement/offloading until manual intervention.
    Down,
}

/// The synchronization substrate tracked by the simulator.
#[derive(Clone, Debug)]
pub struct SyncNet {
    pub cfg: SyncConfig,
    n: usize,
    /// Completion time of each server's last sync round (ms).
    last_sync_ms: Vec<f64>,
    fault: Vec<Fault>,
}

impl SyncNet {
    pub fn new(n: usize, cfg: SyncConfig) -> Self {
        SyncNet {
            cfg,
            n,
            last_sync_ms: vec![0.0; n],
            fault: vec![Fault::Healthy; n],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of ring members currently participating.
    pub fn live_members(&self) -> usize {
        self.fault.iter().filter(|f| !matches!(f, Fault::Down)).count()
    }

    /// Run one sync round completing at `now_ms`: every live server's
    /// state timestamp advances; silent errors past their window heal.
    pub fn advance(&mut self, now_ms: f64) {
        for i in 0..self.n {
            match self.fault[i] {
                Fault::Down => {} // bypassed: state stays stale
                Fault::SilentError { until_ms, .. } if now_ms >= until_ms => {
                    // §5.3.3: "passively resolves ... with automatic
                    // correction during subsequent synchronization cycles"
                    self.fault[i] = Fault::Healthy;
                    self.last_sync_ms[i] = now_ms;
                }
                _ => self.last_sync_ms[i] = now_ms,
            }
        }
    }

    /// t_n: age of the synced state about `server` at `now_ms`.
    pub fn staleness_ms(&self, server: ServerId, now_ms: f64) -> f64 {
        let i = server.0 as usize;
        (now_ms - self.last_sync_ms[i]).max(0.0) + self.round_delay_ms()
    }

    /// Delay of one round over the live membership.
    pub fn round_delay_ms(&self) -> f64 {
        self.cfg.full_sync_delay_ms(self.live_members())
    }

    /// Inject an undetected silent data error about `server` lasting
    /// `duration_ms`: cached goodput about it reads wrong by `factor`.
    pub fn inject_silent_error(&mut self, server: ServerId, now_ms: f64,
                               duration_ms: f64, factor: f64) {
        self.fault[server.0 as usize] =
            Fault::SilentError { until_ms: now_ms + duration_ms, factor };
    }

    /// Detected information loss: flag unresponsive, bypass in the ring
    /// "until manual intervention" (§5.3.3).
    pub fn mark_down(&mut self, server: ServerId) {
        self.fault[server.0 as usize] = Fault::Down;
    }

    /// Manual intervention: bring the server back.
    pub fn repair(&mut self, server: ServerId, now_ms: f64) {
        self.fault[server.0 as usize] = Fault::Healthy;
        self.last_sync_ms[server.0 as usize] = now_ms;
    }

    /// Is the server excluded from offloading/placement?
    pub fn is_down(&self, server: ServerId) -> bool {
        matches!(self.fault[server.0 as usize], Fault::Down)
    }

    /// Distortion the synced view applies to `server`'s reported actual
    /// goodput (silent errors make the cloud misjudge idle capacity).
    pub fn state_distortion(&self, server: ServerId) -> f64 {
        match self.fault[server.0 as usize] {
            Fault::SilentError { factor, .. } => factor,
            _ => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig17d_sync_delay_envelope() {
        // (50 Mb/s, 100 servers) and (500 Mb/s, 1000 servers) ≤ 10 s
        let a = SyncConfig { bandwidth_mbps: 50.0, ..Default::default() };
        assert!(a.full_sync_delay_ms(100) <= 10_000.0,
                "{}", a.full_sync_delay_ms(100));
        let b = SyncConfig { bandwidth_mbps: 500.0, ..Default::default() };
        assert!(b.full_sync_delay_ms(1000) <= 10_000.0,
                "{}", b.full_sync_delay_ms(1000));
    }

    #[test]
    fn delay_grows_with_scale_and_grouping_fixes_it() {
        let flat = SyncConfig::default();
        let d10k = flat.full_sync_delay_ms(10_000);
        let d100 = flat.full_sync_delay_ms(100);
        assert!(d10k > 10.0 * d100, "flat ring must degrade with scale");
        // Fig 18a: groups of 100–500 keep large clouds responsive
        let grouped = SyncConfig { group_size: Some(200), ..Default::default() };
        let dg = grouped.full_sync_delay_ms(10_000);
        assert!(dg < d10k / 5.0, "grouped {dg} vs flat {d10k}");
    }

    #[test]
    fn staleness_tracks_rounds() {
        let mut net = SyncNet::new(4, SyncConfig::default());
        net.advance(1000.0);
        let t = net.staleness_ms(ServerId(2), 1500.0);
        assert!(t >= 500.0 && t < 600.0, "{t}");
        net.advance(2000.0);
        assert!(net.staleness_ms(ServerId(2), 2000.0) < 100.0);
    }

    #[test]
    fn silent_error_self_heals() {
        let mut net = SyncNet::new(3, SyncConfig::default());
        net.inject_silent_error(ServerId(1), 0.0, 500.0, 0.0);
        assert_eq!(net.state_distortion(ServerId(1)), 0.0);
        net.advance(100.0); // too early: error persists
        assert_eq!(net.state_distortion(ServerId(1)), 0.0);
        net.advance(600.0); // next cycle after the window: healed
        assert_eq!(net.state_distortion(ServerId(1)), 1.0);
    }

    #[test]
    fn down_server_bypassed() {
        let mut net = SyncNet::new(5, SyncConfig::default());
        let before = net.round_delay_ms();
        net.mark_down(ServerId(3));
        assert!(net.is_down(ServerId(3)));
        assert_eq!(net.live_members(), 4);
        assert!(net.round_delay_ms() < before);
        net.advance(100.0);
        // the down server's state never refreshes
        assert!(net.staleness_ms(ServerId(3), 100.0)
                > net.staleness_ms(ServerId(0), 100.0));
        net.repair(ServerId(3), 200.0);
        assert!(!net.is_down(ServerId(3)));
    }
}
