//! Online prediction layer (DESIGN.md §Prediction).
//!
//! Two small incremental estimators shared by the wall-clock gateway and
//! the virtual-time simulator:
//!
//! * [`LatencyModel`] — per-(category, service) execution-latency model:
//!   an EWMA mean, an EWMA absolute deviation, and a Robbins–Monro
//!   online quantile estimate.  [`LatencyModel::predict`] returns `None`
//!   until `min_samples` observations have arrived, so consumers fall
//!   back to the static SLO-budget path while the model is cold.
//! * [`RateForecaster`] — short-horizon arrival-rate forecaster: Holt's
//!   double-exponential smoothing (level + trend) over fixed
//!   `bucket_ms` time buckets.  The sim uses it to project a category's
//!   demand at the *next scheduled placement round* and pull the round
//!   forward when the projection crosses provisioned capacity.
//!
//! Everything here is pure `f64` arithmetic on caller-supplied time: no
//! clocks, no RNG, no allocation after construction — so the simulator's
//! bit-exact determinism discipline carries through unchanged, and with
//! `enabled: false` (the default) nothing is even constructed.

/// Knobs for both estimators plus the trigger policy built on them.
#[derive(Clone, Copy, Debug)]
pub struct PredictConfig {
    /// Master switch.  Off (the default) reproduces the pre-prediction
    /// engine bit-for-bit: no model is built, no trigger fires, no
    /// fingerprint token appears.
    pub enabled: bool,
    /// EWMA gain for the latency mean/deviation and the Holt level.
    pub alpha: f64,
    /// Cold-start threshold: `LatencyModel::predict` is `None` (and
    /// admission stays on the static path) below this many samples.
    pub min_samples: u64,
    /// Latency quantile the Robbins–Monro estimator tracks (0, 1).
    pub quantile: f64,
    /// Arrival-rate bucket width for the forecaster (virtual ms in the
    /// sim, wall ms on the gateway).
    pub bucket_ms: f64,
    /// Proactive-round margin: an early placement round fires when the
    /// forecast rate exceeds `provisioned * (1 + margin)`.
    pub margin: f64,
    /// Minimum gap between proactive rounds (ms), so a sustained surge
    /// triggers one early round, not one per arrival.
    pub cooldown_ms: f64,
}

impl Default for PredictConfig {
    fn default() -> Self {
        PredictConfig {
            enabled: false,
            alpha: 0.3,
            min_samples: 64,
            quantile: 0.9,
            bucket_ms: 250.0,
            margin: 0.25,
            cooldown_ms: 1500.0,
        }
    }
}

/// Incremental latency model: EWMA mean + EWMA absolute deviation +
/// Robbins–Monro quantile.  O(1) state, O(1) update.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    alpha: f64,
    q: f64,
    min_samples: u64,
    n: u64,
    mean: f64,
    dev: f64,
    quant: f64,
}

impl LatencyModel {
    pub fn new(cfg: &PredictConfig) -> LatencyModel {
        LatencyModel {
            alpha: cfg.alpha,
            q: cfg.quantile,
            min_samples: cfg.min_samples,
            n: 0,
            mean: 0.0,
            dev: 0.0,
            quant: 0.0,
        }
    }

    /// Fold one latency observation (ms) into the model.
    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() || x < 0.0 {
            return;
        }
        self.n += 1;
        if self.n == 1 {
            self.mean = x;
            self.quant = x;
            self.dev = 0.0;
            return;
        }
        self.mean += self.alpha * (x - self.mean);
        self.dev += self.alpha * ((x - self.mean).abs() - self.dev);
        // Robbins–Monro quantile step, scaled by the deviation estimate
        // so the estimator tracks regime shifts at any latency scale.
        let step = self.dev.max(self.mean.abs() * 1e-3).max(1e-6) * self.alpha;
        if x > self.quant {
            self.quant += step * self.q;
        } else {
            self.quant -= step * (1.0 - self.q);
        }
    }

    /// Predicted per-request execution latency (ms): the tracked
    /// quantile, floored by the mean so a lagging quantile estimate
    /// never undercuts the central tendency.  `None` while cold.
    pub fn predict(&self) -> Option<f64> {
        if self.n < self.min_samples {
            return None;
        }
        Some(self.quant.max(self.mean))
    }

    /// Observations folded so far.
    pub fn samples(&self) -> u64 {
        self.n
    }

    /// Whether `predict` would return a value.
    pub fn warm(&self) -> bool {
        self.n >= self.min_samples
    }
}

/// Minimum closed buckets before the forecaster reports a projection.
const MIN_FORECAST_BUCKETS: u64 = 4;
/// Holt trend gain (level gain comes from `PredictConfig::alpha`).
const TREND_BETA: f64 = 0.2;

/// Short-horizon arrival-rate forecaster: Holt's double-exponential
/// smoothing over fixed time buckets.
#[derive(Clone, Copy, Debug)]
pub struct RateForecaster {
    bucket_ms: f64,
    alpha: f64,
    /// Smoothed arrivals per bucket.
    level: f64,
    /// Smoothed per-bucket trend.
    trend: f64,
    /// Arrivals in the currently open bucket.
    count: f64,
    /// End time of the open bucket.
    bucket_end_ms: f64,
    /// Closed buckets folded into level/trend.
    closed: u64,
}

impl RateForecaster {
    pub fn new(cfg: &PredictConfig) -> RateForecaster {
        RateForecaster {
            bucket_ms: cfg.bucket_ms.max(1.0),
            alpha: cfg.alpha,
            level: 0.0,
            trend: 0.0,
            count: 0.0,
            bucket_end_ms: cfg.bucket_ms.max(1.0),
            closed: 0,
        }
    }

    /// Close every bucket that ended at or before `now_ms` (empty
    /// buckets count as zero arrivals — gaps pull the level down).
    pub fn advance(&mut self, now_ms: f64) {
        while now_ms >= self.bucket_end_ms {
            let x = self.count;
            self.count = 0.0;
            self.bucket_end_ms += self.bucket_ms;
            self.closed += 1;
            if self.closed == 1 {
                self.level = x;
                self.trend = 0.0;
            } else {
                let prev = self.level;
                self.level = self.alpha * x + (1.0 - self.alpha) * (self.level + self.trend);
                self.trend = TREND_BETA * (self.level - prev) + (1.0 - TREND_BETA) * self.trend;
            }
        }
    }

    /// Record one arrival at `now_ms` (also advances the bucket clock).
    pub fn observe(&mut self, now_ms: f64) {
        self.advance(now_ms);
        self.count += 1.0;
    }

    /// Whether enough buckets closed for the projection to mean anything.
    pub fn ready(&self) -> bool {
        self.closed >= MIN_FORECAST_BUCKETS
    }

    /// Projected arrival rate (requests/s) `horizon_ms` from the current
    /// bucket, clamped at zero.  `None` while not [`ready`].
    pub fn forecast_rps(&self, horizon_ms: f64) -> Option<f64> {
        if !self.ready() {
            return None;
        }
        let buckets_ahead = (horizon_ms.max(0.0)) / self.bucket_ms;
        let per_bucket = self.level + self.trend * buckets_ahead;
        Some((per_bucket * 1000.0 / self.bucket_ms).max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PredictConfig {
        PredictConfig { enabled: true, min_samples: 8, ..Default::default() }
    }

    #[test]
    fn latency_model_is_cold_below_min_samples_then_warm() {
        let c = cfg();
        let mut m = LatencyModel::new(&c);
        for i in 0..c.min_samples - 1 {
            m.observe(10.0 + (i % 3) as f64);
            assert_eq!(m.predict(), None, "cold below min_samples");
        }
        m.observe(10.0);
        assert!(m.warm());
        let p = m.predict().expect("warm model must predict");
        assert!(p > 0.0 && p < 100.0, "prediction in the sample range: {p}");
    }

    #[test]
    fn latency_model_tracks_a_regime_shift() {
        let c = cfg();
        let mut m = LatencyModel::new(&c);
        for _ in 0..64 {
            m.observe(10.0);
        }
        let before = m.predict().unwrap();
        assert!((before - 10.0).abs() < 1.0, "steady stream pins ~10: {before}");
        for _ in 0..256 {
            m.observe(100.0);
        }
        let after = m.predict().unwrap();
        assert!(after > 60.0, "model must follow the 10→100 shift: {after}");
    }

    #[test]
    fn latency_model_quantile_sits_above_the_mean_on_skewed_input() {
        let c = cfg();
        let mut m = LatencyModel::new(&c);
        // 90% fast, 10% slow: p90 tracking must exceed the plain mean of
        // the fast mass
        for i in 0..2000 {
            m.observe(if i % 10 == 9 { 80.0 } else { 8.0 });
        }
        let p = m.predict().unwrap();
        assert!(p > 9.0, "skew-aware prediction above the fast mass: {p}");
    }

    #[test]
    fn latency_model_ignores_garbage_samples() {
        let c = cfg();
        let mut m = LatencyModel::new(&c);
        for _ in 0..16 {
            m.observe(10.0);
        }
        let n = m.samples();
        m.observe(f64::NAN);
        m.observe(f64::INFINITY);
        m.observe(-5.0);
        assert_eq!(m.samples(), n, "non-finite / negative samples dropped");
        assert!(m.predict().unwrap().is_finite());
    }

    #[test]
    fn forecaster_not_ready_until_min_buckets() {
        let c = cfg();
        let mut f = RateForecaster::new(&c);
        f.observe(10.0);
        assert!(!f.ready());
        assert_eq!(f.forecast_rps(500.0), None);
        // walk past MIN_FORECAST_BUCKETS bucket ends
        f.advance(c.bucket_ms * (MIN_FORECAST_BUCKETS as f64 + 0.5));
        assert!(f.ready());
        assert!(f.forecast_rps(500.0).is_some());
    }

    #[test]
    fn forecaster_tracks_a_steady_rate() {
        let c = cfg();
        let mut f = RateForecaster::new(&c);
        // 40 req/s = 10 per 250 ms bucket, for 5 s
        for i in 0..200 {
            f.observe(i as f64 * 25.0);
        }
        let rps = f.forecast_rps(0.0).unwrap();
        assert!((rps - 40.0).abs() < 8.0, "steady 40 req/s, got {rps}");
    }

    #[test]
    fn forecaster_projects_a_surge_upward() {
        let c = cfg();
        let mut f = RateForecaster::new(&c);
        // 2 s at 40 req/s, then 1 s at 120 req/s
        for i in 0..80 {
            f.observe(i as f64 * 25.0);
        }
        let calm = f.forecast_rps(1000.0).unwrap();
        for i in 0..120 {
            f.observe(2000.0 + i as f64 * (1000.0 / 120.0));
        }
        let hot = f.forecast_rps(1000.0).unwrap();
        assert!(
            hot > calm * 1.5,
            "surge must lift the projection: calm {calm} hot {hot}"
        );
    }

    #[test]
    fn forecaster_decays_through_empty_buckets() {
        let c = cfg();
        let mut f = RateForecaster::new(&c);
        for i in 0..80 {
            f.observe(i as f64 * 25.0);
        }
        let busy = f.forecast_rps(0.0).unwrap();
        // 5 s of silence: closing empty buckets pulls the level down
        f.advance(7000.0);
        let idle = f.forecast_rps(0.0).unwrap();
        assert!(idle < busy * 0.25, "silence must decay the rate: {busy} → {idle}");
    }

    #[test]
    fn estimators_are_deterministic() {
        let c = cfg();
        let run = || {
            let mut m = LatencyModel::new(&c);
            let mut f = RateForecaster::new(&c);
            for i in 0..500 {
                m.observe(5.0 + (i % 7) as f64);
                f.observe(i as f64 * 13.0);
            }
            (m.predict().unwrap().to_bits(), f.forecast_rps(750.0).unwrap().to_bits())
        };
        assert_eq!(run(), run(), "pure-f64 estimators must be bit-stable");
    }
}
