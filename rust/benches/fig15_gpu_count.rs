//! Fig. 15 — GPUs needed to complete all inference requests within SLO
//! (paper: EPARA requires 1.5–2.6× fewer GPUs than the baselines because
//! it schedules across servers and parallelizes by category).
//!
//! Regenerate with:  cargo bench --bench fig15_gpu_count

use epara::cluster::{EdgeCloud, GpuSpec, Link};
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn gpus_needed(policy: PolicyConfig, rps: f64, target: f64) -> Option<usize> {
    let table = zoo::paper_zoo();
    for per_server in [1usize, 2, 3, 4, 6, 8, 12, 16, 24, 32] {
        let cloud = EdgeCloud::uniform(8, per_server, GpuSpec::P100,
                                       Link::SWITCH_10G);
        let spec = WorkloadSpec {
            mix: Mix::Production(3),
            rps,
            duration_ms: 12_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let cfg = SimConfig { policy, duration_ms: 12_000.0, ..Default::default() };
        let m = simulate(&table, cloud, reqs, cfg);
        if m.satisfaction_ratio() >= target {
            return Some(8 * per_server);
        }
    }
    None
}

fn main() {
    println!("## Fig 15 — GPUs required to serve the load within SLO \
              (8 servers, scale-up per server)");
    println!("{:>10} {:>14} {:>10}", "load", "scheme", "GPUs");
    let mut epara_gpus = Vec::new();
    for rps in [150.0, 300.0, 600.0] {
        for policy in [PolicyConfig::epara(), PolicyConfig::interedge(),
                       PolicyConfig::alpaserve(), PolicyConfig::galaxy()] {
            let g = gpus_needed(policy, rps, 0.95);
            if policy.name == "EPARA" {
                epara_gpus.push(g);
            }
            println!("{rps:>10.0} {:>14} {:>10}",
                     policy.name,
                     g.map(|v| v.to_string()).unwrap_or_else(|| ">256".into()));
        }
        println!();
    }
    println!("(paper: EPARA needs 1.5-2.6x fewer GPUs)");
}
