//! Fig. 18 — extreme cases.
//!
//! (a) scalability: goodput vs server count, flat ring vs grouped sync
//!     (paper: sub-linear growth past a threshold; 100–500-server groups
//!     restore scalability);
//! (b) latency breakdown at scale: handling vs sync vs placement;
//! (c/d) device-saturated servers: registration queueing latency;
//! (e) GPU-sparse system under 10× overload: goodput holds.
//!
//! Regenerate with:  cargo bench --bench fig18_extreme

use epara::cluster::{EdgeCloud, GpuSpec, Link};
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::sync::SyncConfig;
use epara::util::stats::Summary;
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() {
    let table = zoo::paper_zoo();

    println!("## Fig 18a — sync delay at scale: flat ring vs grouped");
    println!("{:>9} {:>14} {:>16}", "servers", "flat (ms)", "grouped200 (ms)");
    for n in [100usize, 500, 1000, 5000, 10_000, 50_000] {
        let flat = SyncConfig::default().full_sync_delay_ms(n);
        let grouped = SyncConfig { group_size: Some(200), ..Default::default() }
            .full_sync_delay_ms(n);
        println!("{n:>9} {flat:>14.1} {grouped:>16.1}");
    }
    println!("(paper: grouping 100-500 servers/exchange restores scalability)\n");

    println!("## Fig 18b — component latency at scale (model)");
    println!("{:>9} {:>14} {:>14} {:>14}",
             "servers", "handling (ms)", "sync (ms)", "placement (ms)");
    for n in [100usize, 1000, 10_000] {
        // handling stays O(candidates): measured in fig03; sync/placement
        // grow — sync from the ring model, placement measured in fig17c.
        let sync = SyncConfig { group_size: Some(200), ..Default::default() }
            .full_sync_delay_ms(n);
        let handling = 0.02 * (n as f64 / 100.0).max(1.0).log2().max(1.0);
        let placement = 2.0 + n as f64 * 0.012; // fig17c fit
        println!("{n:>9} {handling:>14.3} {sync:>14.1} {placement:>14.1}");
    }
    println!();

    println!("## Fig 18c/d — device-saturated registration (queueing model)");
    // Devices register at one server; model loading serializes on the
    // server's management path (bandwidth-capped): the i-th registration
    // to be served waits i·load_ms.  Percentiles come from the shared
    // util::stats helpers (same code path as the gateway's /metrics).
    println!("{:>12} {:>18} {:>14} {:>14}",
             "concurrent", "assign p50 (ms)", "p95 (ms)", "p99 (ms)");
    let load_ms = 40.0; // tiny model push to a Jetson over WiFi
    for k in [1usize, 4, 16, 64, 256] {
        let mut wait = Summary::new();
        wait.extend((1..=k).map(|i| i as f64 * load_ms));
        let (p50, p95, p99) = wait.p50_p95_p99();
        println!("{k:>12} {p50:>18.0} {p95:>14.0} {p99:>14.0}");
    }
    println!("(queueing states appear past the concurrency threshold)\n");

    println!("## Fig 18e — GPU-sparse system, 10x overload");
    let sparse = EdgeCloud::uniform(3, 1, GpuSpec::P100, Link::SWITCH_10G);
    println!("{:>8} {:>12} {:>10}", "load", "goodput", "ratio");
    let mut base_goodput = 0.0;
    for mult in [1.0, 2.0, 5.0, 10.0] {
        let spec = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 40.0 * mult,
            duration_ms: 15_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &sparse);
        let cfg = SimConfig {
            policy: PolicyConfig::epara(),
            duration_ms: 15_000.0,
            ..Default::default()
        };
        let m = simulate(&table, sparse.clone(), reqs, cfg);
        if mult == 1.0 {
            base_goodput = m.goodput_rps();
        }
        println!("{:>7.0}x {:>12.1} {:>10.2}",
                 mult, m.goodput_rps(), m.goodput_rps() / base_goodput.max(1e-9));
    }
    println!("(paper: max feasible requests fulfilled, no throughput collapse)");
}
