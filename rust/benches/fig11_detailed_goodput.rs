//! Fig. 11 — detailed per-workload goodput and the §5.1.1 stability
//! claims: below max goodput EPARA fulfils requests with >99.4%
//! probability; above it, goodput holds at >= 98.1% of max.
//!
//! Regenerate with:  cargo bench --bench fig11_detailed_goodput

use epara::cluster::EdgeCloud;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn run(w: u8, rps: f64) -> epara::metrics::Metrics {
    let table = zoo::paper_zoo();
    let spec = WorkloadSpec {
        mix: Mix::Production(w),
        rps,
        duration_ms: 20_000.0,
        seed: 42 + w as u64,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &EdgeCloud::testbed());
    let cfg = SimConfig {
        policy: PolicyConfig::epara(),
        duration_ms: 20_000.0,
        ..Default::default()
    };
    simulate(&table, EdgeCloud::testbed(), reqs, cfg)
}

fn main() {
    println!("## Fig 11 — EPARA goodput vs offered load per workload");
    println!("{:>9} {:>10} {:>12} {:>12} {:>10}",
             "workload", "load", "goodput", "satisfied", "ratio");
    for w in 0..5u8 {
        for rps in [25.0, 100.0, 250.0, 500.0] {
            let m = run(w, rps);
            println!("{:>9} {rps:>10.0} {:>12.1} {:>12.1} {:>10.3}",
                     format!("W{w}"), m.goodput_rps(), m.satisfied,
                     m.satisfaction_ratio());
        }
    }

    println!("\n## §5.1.1 stability claims");
    // find (roughly) max goodput, then check below/above behaviour
    let mut max_goodput = 0.0f64;
    for rps in [100.0, 200.0, 300.0, 400.0, 600.0, 800.0] {
        max_goodput = max_goodput.max(run(0, rps).goodput_rps());
    }
    let light = run(0, 15.0);
    let over = run(0, 1200.0);
    println!("light-load fulfilment ratio : {:.4}  (paper: > 0.994)",
             light.satisfaction_ratio());
    println!("max goodput observed        : {max_goodput:.1} req/s");
    println!("overload goodput retention  : {:.3}  (paper: >= 0.981)",
             over.goodput_rps() / max_goodput.max(1e-9));
}
