//! Fig. 16 — effect of the task-categorized parallelism allocator:
//! per-GPU goodput of the allocated configuration vs non-parallelism
//! deployment (BS1/MT1/MP-None/MF1/DP1), per category.
//!
//! Paper: ≤1 GPU frequency 5.9–12.4×; >1 GPU frequency 1.3–2.5×;
//! ≤1 GPU latency 2.3–9.1×; >1 GPU latency 2.9–4.5×.
//!
//! Regenerate with:  cargo bench --bench fig16_allocator

use epara::allocator::{Allocator, Overrides};
use epara::cluster::GpuSpec;
use epara::core::{OperatorConfig, TaskCategory};
use epara::profile::zoo;

fn main() {
    let table = zoo::paper_zoo();
    let alloc = Allocator::new(&table, GpuSpec::P100);
    let naive = OperatorConfig::default();

    let claims = [
        (TaskCategory::FrequencySingle, "5.9-12.4x"),
        (TaskCategory::FrequencyMulti, "1.3-2.5x"),
        (TaskCategory::LatencySingle, "2.3-9.1x"),
        (TaskCategory::LatencyMulti, "2.9-4.5x"),
    ];

    for (cat, claim) in claims {
        println!("## Fig 16 — {cat:?} (paper: {claim} per-GPU goodput)");
        println!("{:>20} {:>8} {:>4} {:>4} {:>9} {:>4} {:>4} {:>12} {:>12} {:>7}",
                 "service", "", "BS", "MT", "MP", "MF", "DP",
                 "epara/GPU", "naive/GPU", "gain");
        let mut services: Vec<_> = table
            .services()
            .filter(|s| alloc.categorize(s.id) == cat)
            .collect();
        services.sort_by_key(|s| s.id);
        for s in services {
            let al = alloc.allocate(s.id, Overrides::default());
            let ours = alloc.per_gpu_goodput(s.id, &al.ops);
            // naive cannot run multi-GPU models at all: report n/a
            let base = if s.fits_single_gpu(GpuSpec::P100.vram_mb) {
                alloc.per_gpu_goodput(s.id, &naive)
            } else {
                // smallest feasible MP config, still BS1/MT1/no request-level
                let min_mp = alloc.default_mp(s.id, al.category);
                alloc.per_gpu_goodput(s.id, &OperatorConfig {
                    mp: min_mp, ..naive
                })
            };
            println!("{:>20} {:>8} {:>4} {:>4} {:>9} {:>4} {:>4} {:>12.1} {:>12.1} {:>6.1}x",
                     s.name, "", al.ops.bs, al.ops.mt,
                     format!("{:?}", al.ops.mp), al.ops.mf, al.ops.dp,
                     ours, base, ours / base.max(1e-9));
        }
        println!();
    }
}
