//! Ablations over EPARA's design-choice parameters (DESIGN.md §Design):
//!
//! * maximum offloading count (§4.1: default 5 — "each offloading attempt
//!   has a high likelihood of being processed");
//! * placement refresh interval (§3.4 coarse granularity vs Fig. 3f
//!   model-load cost);
//! * the ε-stage (cross-server parallelism) on/off;
//! * device registration on/off.
//!
//! Regenerate with:  cargo bench --bench ablation_params

use epara::cluster::EdgeCloud;
use epara::handler::HandlerConfig;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn run(cfg: SimConfig, rps: f64, seed: u64) -> epara::metrics::Metrics {
    let table = zoo::paper_zoo();
    let spec = WorkloadSpec {
        mix: Mix::Production(0),
        rps,
        seed,
        duration_ms: 15_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &EdgeCloud::testbed());
    simulate(&table, EdgeCloud::testbed(), reqs, cfg)
}

fn main() {
    println!("## Ablation — maximum offloading count (§4.1, default 5)");
    println!("{:>6} {:>12} {:>12} {:>10}", "max", "goodput", "satisfied", "offloads");
    for max_offloads in [0u32, 1, 2, 5, 10] {
        let cfg = SimConfig {
            handler: HandlerConfig { max_offloads },
            duration_ms: 15_000.0,
            ..Default::default()
        };
        let m = run(cfg, 250.0, 3);
        println!("{max_offloads:>6} {:>12.1} {:>12.1} {:>10.3}",
                 m.goodput_rps(), m.satisfied, m.mean_offloads());
    }
    println!();

    println!("## Ablation — placement refresh interval (§3.4)");
    println!("{:>12} {:>12} {:>12}", "interval", "goodput", "satisfied");
    for interval in [None, Some(1_000.0), Some(2_000.0), Some(5_000.0)] {
        let cfg = SimConfig {
            replacement_interval_ms: interval,
            duration_ms: 15_000.0,
            ..Default::default()
        };
        let m = run(cfg, 250.0, 3);
        let label = interval
            .map(|v| format!("{v:.0} ms"))
            .unwrap_or_else(|| "offline".into());
        println!("{label:>12} {:>12.1} {:>12.1}", m.goodput_rps(), m.satisfied);
    }
    println!();

    println!("## Ablation — ε-stage (cross-server parallelism) and devices");
    println!("{:>24} {:>12} {:>12}", "config", "goodput", "satisfied");
    for (label, cross, device) in [
        ("full EPARA", true, true),
        ("no cross-server MP", false, true),
        ("no device GPUs", true, false),
        ("neither", false, false),
    ] {
        let mut policy = PolicyConfig::epara();
        policy.allow_cross_server = cross;
        policy.allow_device = device;
        let cfg = SimConfig { policy, duration_ms: 15_000.0, ..Default::default() };
        let m = run(cfg, 250.0, 3);
        println!("{label:>24} {:>12.1} {:>12.1}", m.goodput_rps(), m.satisfied);
    }
}
