//! Fig. 14 — large-scale simulation goodput: latency-only (EPARA
//! 1.5–2.0×), frequency-only (2.8–3.1×), mixed (1.6–2.4×) vs baselines,
//! over clusters of N servers × 8 P100.
//!
//! Regenerate with:  cargo bench --bench fig14_large_scale
//! (EPARA_MAX_SERVERS bounds the sweep; default 16 keeps the run short.)

use epara::cluster::EdgeCloud;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() {
    let table = zoo::paper_zoo();
    let max_servers: usize = std::env::var("EPARA_MAX_SERVERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let policies = [
        PolicyConfig::epara(),
        PolicyConfig::interedge(),
        PolicyConfig::alpaserve(),
        PolicyConfig::galaxy(),
        PolicyConfig::servp(),
        PolicyConfig::usher(),
        PolicyConfig::detransformer(),
    ];

    for (mix, label, claim) in [
        (Mix::LatencyOnly, "latency-sensitive", "1.5-2.0x"),
        (Mix::FrequencyOnly, "frequency-sensitive", "2.8-3.1x"),
        (Mix::Mixed, "mixed", "1.6-2.4x"),
    ] {
        println!("## Fig 14 — {label} requests (paper claim: EPARA {claim})");
        print!("{:>8}", "servers");
        for p in &policies {
            print!(" {:>13}", p.name);
        }
        println!();
        let mut n = 4usize;
        while n <= max_servers {
            let load = 50.0 * n as f64;
            print!("{n:>8}");
            let mut vals = Vec::new();
            for p in &policies {
                let cloud = EdgeCloud::large_scale(n);
                let spec = WorkloadSpec {
                    mix,
                    rps: load,
                    streams: 30 * n,
                    duration_ms: 12_000.0,
                    ..Default::default()
                };
                let reqs = generate(&spec, &table, &cloud);
                let cfg = SimConfig { policy: *p, duration_ms: 12_000.0,
                                      ..Default::default() };
                let m = simulate(&table, cloud, reqs, cfg);
                vals.push(m.goodput_rps());
                print!(" {:>13.1}", m.goodput_rps());
            }
            println!();
            n *= 2;
        }
        println!();
    }
}
