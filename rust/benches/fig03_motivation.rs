//! Fig. 3 — motivation micro-benchmarks.
//!
//! (a) DP round-robin frame-rate scaling (49 → ~97 fps with 2 GPUs);
//! (b) MP fps gains for heavy segmentation (paper: up to 4.8×);
//! (c) multi-task GPU throughput (paper: 1.7×);
//! (d) batching throughput (paper: 6.9×);
//! (e) centralized scheduling latency vs server count (>100 ms @10,
//!     >750 ms @30+) vs EPARA's decentralized handler;
//! (f) model placement vs single-task processing time (≥2.5×).
//!
//! Regenerate with:  cargo bench --bench fig03_motivation

use epara::core::MpKind;
use epara::profile::zoo::{self, ids};
use epara::sim::PolicyConfig;

fn main() {
    let t = zoo::paper_zoo();

    println!("## Fig 3a — DP round-robin fps scaling (DeeplabV3+ video)");
    println!("{:>6} {:>10}", "GPUs", "fps");
    let one = t.throughput(ids::DEEPLABV3P, 1, MpKind::None, 1);
    for k in 1..=4u32 {
        println!("{k:>6} {:>10.1}", one * k as f64);
    }
    println!("(paper: 49 -> 97 fps at 2 GPUs)\n");

    println!("## Fig 3b — MP strategies, heavy model fps (OMG-Seg)");
    println!("{:>10} {:>10} {:>8}", "MP", "fps", "gain");
    let base = t.throughput(ids::OMG_SEG, 1, MpKind::Pp(2), 1); // min config that fits
    for (label, mp) in [("PP2", MpKind::Pp(2)), ("TP2", MpKind::Tp(2)),
                        ("TP2+PP2", MpKind::TpPp(2, 2)), ("PP4", MpKind::Pp(4)),
                        ("TP2+PP4", MpKind::TpPp(2, 4))] {
        let fps = t.throughput(ids::OMG_SEG, 1, mp, 1);
        println!("{label:>10} {fps:>10.2} {:>7.1}x", fps / base);
    }
    println!("(paper: optimized MP up to 4.8x fps)\n");

    println!("## Fig 3c — multi-task throughput (ResNet50, MPS slices)");
    println!("{:>6} {:>12} {:>8}", "MT", "items/s", "gain");
    let base = t.throughput(ids::RESNET50, 4, MpKind::None, 1);
    for mt in [1u32, 2, 4, 8] {
        let tp = t.throughput(ids::RESNET50, 4, MpKind::None, mt);
        println!("{mt:>6} {tp:>12.1} {:>7.1}x", tp / base);
    }
    println!("(paper: superior multi-task 1.7x)\n");

    println!("## Fig 3d — batching throughput (MobileNetV2)");
    println!("{:>6} {:>12} {:>8}", "BS", "items/s", "gain");
    let base = t.throughput(ids::MOBILENET_V2, 1, MpKind::None, 1);
    for bs in [1u32, 2, 4, 8, 16, 32, 64] {
        let tp = t.throughput(ids::MOBILENET_V2, bs, MpKind::None, 1);
        println!("{bs:>6} {tp:>12.1} {:>7.1}x", tp / base);
    }
    println!("(paper: batching up to 6.9x)\n");

    println!("## Fig 3e — per-request scheduling latency vs servers");
    println!("{:>8} {:>14} {:>14}", "servers", "SERV-P (ms)", "EPARA (ms)");
    let servp = PolicyConfig::servp();
    for n in [5usize, 10, 20, 30, 50, 100] {
        // EPARA's decentralized handler cost: measured below in Fig 17,
        // bounded by the O(candidates) scan — microseconds. Report the
        // measured per-decision wall time.
        let epara_ms = measure_handler_decision_ms(n);
        println!("{n:>8} {:>14.0} {epara_ms:>14.3}", servp.central_latency_ms(n));
    }
    println!("(paper: >100 ms at 10 nodes, >750 ms beyond 30)\n");

    println!("## Fig 3f — model placement vs single-task time");
    println!("{:>14} {:>10} {:>10} {:>8}", "model", "load ms", "infer ms", "ratio");
    for id in [ids::RESNET50, ids::YOLOV10, ids::UNET, ids::QWEN_1_5B] {
        let spec = t.spec(id);
        let infer = t.latency_ms(id, 1, MpKind::None, 1);
        println!("{:>14} {:>10.0} {:>10.1} {:>7.1}x",
                 spec.name, spec.model_load_ms, infer,
                 spec.model_load_ms / infer);
    }
    println!("(paper: ResNet50 550/60 ms — placement >= 2.5x processing)");
}

fn measure_handler_decision_ms(n: usize) -> f64 {
    use epara::core::{Request, RequestId, ServerId, ServiceId};
    use epara::handler::{decide, HandlerConfig, LocalCapacity, StateView};
    use epara::util::Rng;

    struct V {
        n: usize,
        theo: Vec<f64>,
    }
    impl StateView for V {
        fn n_servers(&self) -> usize { self.n }
        fn local_capacity(&self, _: ServerId, _: ServiceId) -> LocalCapacity {
            LocalCapacity::None
        }
        fn theoretical_goodput(&self, s: ServerId, _: ServiceId) -> f64 {
            self.theo[s.0 as usize]
        }
        fn actual_goodput(&self, _: ServerId, _: ServiceId) -> f64 { 0.1 }
        fn queued_ms(&self, _: ServerId, _: ServiceId) -> f64 { 5.0 }
        fn sync_delay_ms(&self, _: ServerId) -> f64 { 50.0 }
        fn slo_ms(&self, _: ServiceId) -> f64 { 500.0 }
    }
    let view = V { n, theo: (0..n).map(|i| (i % 7) as f64 + 1.0).collect() };
    let req = Request {
        id: RequestId(0), service: ServiceId(0), arrival_ms: 0.0,
        origin: ServerId(0), frames: 1, path: vec![], offloads: 0,
    };
    let mut rng = Rng::new(5);
    let cfg = HandlerConfig::default();
    let reps = 2000;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let _ = decide(&req, ServerId(0), 1.0, &view, &cfg, &mut rng);
    }
    t0.elapsed().as_secs_f64() * 1000.0 / reps as f64
}
