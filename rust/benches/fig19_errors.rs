//! Fig. 19 — sensitivity and error handling.
//!
//! (a) synchronization errors: undetected silent data errors self-heal at
//!     the next cycle (offload count bump, negligible throughput loss);
//!     detected loss → ring bypass, serving continuity;
//! (b) server/GPU error: fault containment — faulty GPUs and their
//!     parallel peers excluded, no propagation.
//!
//! Regenerate with:  cargo bench --bench fig19_errors

use epara::cluster::EdgeCloud;
use epara::core::ServerId;
use epara::profile::zoo;
use epara::sim::{PolicyConfig, SimConfig, Simulator};
use epara::workload::{generate, Mix, WorkloadSpec};

fn baseline() -> (epara::profile::ProfileTable, Vec<epara::core::Request>, SimConfig) {
    let table = zoo::paper_zoo();
    let spec = WorkloadSpec {
        mix: Mix::Production(0),
        rps: 150.0,
        duration_ms: 20_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &EdgeCloud::testbed());
    let cfg = SimConfig {
        policy: PolicyConfig::epara(),
        duration_ms: 20_000.0,
        ..Default::default()
    };
    (table, reqs, cfg)
}

fn main() {
    println!("## Fig 19a — synchronization error handling");
    println!("{:>24} {:>12} {:>12} {:>12}",
             "scenario", "goodput", "ratio", "offloads");

    let (table, reqs, cfg) = baseline();
    let healthy = {
        let mut sim = Simulator::new(&table, EdgeCloud::testbed(), &reqs, cfg.clone());
        sim.run(reqs.clone()).clone()
    };
    println!("{:>24} {:>12.1} {:>12.2} {:>12.3}",
             "healthy", healthy.goodput_rps(), 1.0, healthy.mean_offloads());

    // undetected silent data error about server 1 for 3 s
    let silent = {
        let mut sim = Simulator::new(&table, EdgeCloud::testbed(), &reqs, cfg.clone());
        sim.sync_mut().inject_silent_error(ServerId(1), 0.0, 3000.0, 0.0);
        sim.run(reqs.clone()).clone()
    };
    println!("{:>24} {:>12.1} {:>12.2} {:>12.3}",
             "silent error (3s)", silent.goodput_rps(),
             silent.goodput_rps() / healthy.goodput_rps(),
             silent.mean_offloads());

    // detected loss: server 1 unresponsive, ring bypasses it
    let down = {
        let mut sim = Simulator::new(&table, EdgeCloud::testbed(), &reqs, cfg.clone());
        sim.sync_mut().mark_down(ServerId(1));
        sim.run(reqs.clone()).clone()
    };
    println!("{:>24} {:>12.1} {:>12.2} {:>12.3}",
             "detected loss (bypass)", down.goodput_rps(),
             down.goodput_rps() / healthy.goodput_rps(),
             down.mean_offloads());
    println!("(paper: silent errors marginally raise offloads, negligible \
              throughput impact)\n");

    println!("## Fig 19b — GPU failure containment");
    println!("{:>24} {:>12} {:>12}", "scenario", "goodput", "ratio");
    let failed = {
        let mut sim = Simulator::new(&table, EdgeCloud::testbed(), &reqs, cfg);
        sim.fail_gpu_containment(ServerId(0));
        sim.run(reqs.clone()).clone()
    };
    println!("{:>24} {:>12.1} {:>12.2}",
             "server0 GPUs failed", failed.goodput_rps(),
             failed.goodput_rps() / healthy.goodput_rps());
    println!("(paper: faults contained; system keeps serving from healthy \
              resources)");
}
