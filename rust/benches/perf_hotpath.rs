//! Perf microbenches for the L3 hot paths (DESIGN.md §Perf).
//!
//! Targets:
//!  * handler decision   — <20 ms at 10k servers (paper §5.3.1; we aim µs);
//!  * placement solve    — <200 ms at 10k servers (Fig. 17c);
//!  * simulator          — >= 100k events/s;
//!  * fluid gain query   — O(1), tens of ns.
//!
//! Regenerate with:  cargo bench --bench perf_hotpath

use std::collections::HashMap;
use std::time::Instant;

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec};
use epara::core::{Request, RequestId, ServerId, ServiceId};
use epara::handler::{decide, HandlerConfig, LocalCapacity, StateView};
use epara::placement::{sssp, FluidEval, PhiEval, PlacementItem};
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::util::Rng;
use epara::workload::{generate, Mix, WorkloadSpec};

struct FlatView {
    n: usize,
    theo: Vec<f64>,
}

impl StateView for FlatView {
    fn n_servers(&self) -> usize { self.n }
    fn local_capacity(&self, _: ServerId, _: ServiceId) -> LocalCapacity {
        LocalCapacity::None
    }
    fn theoretical_goodput(&self, s: ServerId, _: ServiceId) -> f64 {
        self.theo[s.0 as usize]
    }
    fn actual_goodput(&self, _: ServerId, _: ServiceId) -> f64 { 0.3 }
    fn queued_ms(&self, _: ServerId, _: ServiceId) -> f64 { 3.0 }
    fn sync_delay_ms(&self, _: ServerId) -> f64 { 40.0 }
    fn slo_ms(&self, _: ServiceId) -> f64 { 500.0 }
}

fn bench_handler(n: usize) -> f64 {
    let view = FlatView { n, theo: (0..n).map(|i| 1.0 + (i % 5) as f64).collect() };
    let req = Request {
        id: RequestId(0), service: ServiceId(0), arrival_ms: 0.0,
        origin: ServerId(0), frames: 1, path: vec![], offloads: 0,
    };
    let cfg = HandlerConfig::default();
    let mut rng = Rng::new(3);
    let reps = if n >= 10_000 { 200 } else { 5000 };
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = decide(&req, ServerId(0), 1.0, &view, &cfg, &mut rng);
    }
    t0.elapsed().as_secs_f64() * 1000.0 / reps as f64
}

fn main() {
    println!("## L3 hot-path microbenchmarks\n");

    println!("handler decision latency (paper: <20 ms @10k servers):");
    for n in [10usize, 100, 1000, 10_000] {
        println!("  {n:>6} servers: {:>10.4} ms/decision", bench_handler(n));
    }

    println!("\nplacement solve (Fig 17c target <200 ms @10k servers):");
    let table = zoo::paper_zoo();
    for n in [100usize, 1000, 10_000] {
        let cloud = EdgeCloud::large_scale(n);
        let spec = WorkloadSpec {
            rps: 20.0 * n as f64,
            streams: (4 * n).min(40_000),
            duration_ms: 10_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let services: Vec<ServiceId> = {
            let mut s: Vec<_> = reqs.iter().map(|r| r.service).collect();
            s.sort();
            s.dedup();
            s
        };
        let allocator = Allocator::new(&table, GpuSpec::P100);
        let allocs: HashMap<ServiceId, _> = services
            .iter()
            .map(|&id| (id, allocator.allocate(id, Overrides::default())))
            .collect();
        let t0 = Instant::now();
        let mut eval =
            FluidEval::from_requests(&table, &allocs, &cloud, &reqs, 10_000.0);
        let build_ms = t0.elapsed().as_secs_f64() * 1000.0;
        let t0 = Instant::now();
        let placement = sssp(&[], &services, n, &mut eval);
        let solve_ms = t0.elapsed().as_secs_f64() * 1000.0;
        println!("  {n:>6} servers: build {build_ms:>8.1} ms, solve \
                  {solve_ms:>8.1} ms, {} items", placement.len());

        // fluid gain query cost
        let item = PlacementItem { service: services[0], server: ServerId(0) };
        let t0 = Instant::now();
        let reps = 100_000;
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += eval.gain(item);
        }
        let ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
        println!("          gain query: {ns:.0} ns (acc {acc:.1})");
    }

    println!("\nsimulator event throughput:");
    let cloud = EdgeCloud::testbed();
    let spec = WorkloadSpec {
        mix: Mix::Production(0),
        rps: 400.0,
        duration_ms: 30_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    let n_reqs = reqs.len();
    let cfg = SimConfig {
        policy: PolicyConfig::epara(),
        duration_ms: 30_000.0,
        ..Default::default()
    };
    let t0 = Instant::now();
    let m = simulate(&table, cloud, reqs, cfg);
    let wall = t0.elapsed().as_secs_f64();
    // every request generates >= 2 events (arrive + finish) + hops
    let events = (m.offered * 2) as f64 * (1.0 + m.mean_offloads());
    println!("  {n_reqs} requests / {wall:.3} s wall = {:.0} req/s, \
              ~{:.0} events/s",
             n_reqs as f64 / wall, events / wall);
}
