//! Perf microbenches for the L3 hot paths (DESIGN.md §Perf).
//!
//! Targets:
//!  * handler decision   — <20 ms at 10k servers (paper §5.3.1; we aim µs);
//!  * placement solve    — <200 ms at 10k servers (Fig. 17c);
//!  * simulator          — >= 100k events/s;
//!  * fluid gain query   — O(1), tens of ns;
//!  * cache score        — weight-cache admit/warm_frac, sub-µs;
//!  * resilience decide  — breaker admit/record + retry budget, sub-µs;
//!  * predict update     — latency-model observe + forecaster fold, sub-µs;
//!  * timer wheel        — reactor deadline bookkeeping, O(expired)/tick.
//!
//! Usage:
//!   cargo bench --bench perf_hotpath                      # human report
//!   cargo bench --bench perf_hotpath -- --quick           # CI-sized run
//!   cargo bench --bench perf_hotpath -- --json PATH       # also emit JSON
//!
//! `--json` writes the machine-readable record the CI perf gate compares
//! against the checked-in baseline (`BENCH_perf.json` at the repo root;
//! refresh with `make bench-perf` and commit the result).

use std::collections::HashMap;
use std::time::Instant;

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec};
use epara::core::{Request, RequestId, ServerId, ServiceId};
use epara::handler::{decide_with, HandlerConfig, LocalCapacity, OffloadScratch, StateView};
use epara::placement::{sssp, FluidEval, PhiEval, PlacementItem};
use epara::predict::{LatencyModel, PredictConfig, RateForecaster};
use epara::profile::zoo;
use epara::server::resilience::{Admit, Breaker, ResilienceConfig, RetryBudget};
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::util::{Rng, TimerWheel};
use epara::workload::{generate, Mix, WorkloadSpec};

struct FlatView {
    n: usize,
    theo: Vec<f64>,
}

impl StateView for FlatView {
    fn n_servers(&self) -> usize { self.n }
    fn local_capacity(&self, _: ServerId, _: ServiceId) -> LocalCapacity {
        LocalCapacity::None
    }
    fn theoretical_goodput(&self, s: ServerId, _: ServiceId) -> f64 {
        self.theo[s.0 as usize]
    }
    fn actual_goodput(&self, _: ServerId, _: ServiceId) -> f64 { 0.3 }
    fn queued_ms(&self, _: ServerId, _: ServiceId) -> f64 { 3.0 }
    fn sync_delay_ms(&self, _: ServerId) -> f64 { 40.0 }
    fn slo_ms(&self, _: ServiceId) -> f64 { 500.0 }
}

/// Mean decide latency (ms) at `n` servers, steady-state scratch reuse.
fn bench_handler(n: usize) -> f64 {
    let view = FlatView { n, theo: (0..n).map(|i| 1.0 + (i % 5) as f64).collect() };
    let req = Request {
        id: RequestId(0), service: ServiceId(0), arrival_ms: 0.0,
        origin: ServerId(0), frames: 1, path: vec![], offloads: 0,
    };
    let cfg = HandlerConfig::default();
    let mut rng = Rng::new(3);
    let mut scratch = OffloadScratch::new();
    let reps = if n >= 10_000 { 200 } else { 5000 };
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = decide_with(&req, ServerId(0), 1.0, &view, &cfg, &mut rng, &mut scratch);
    }
    t0.elapsed().as_secs_f64() * 1000.0 / reps as f64
}

/// Resolve a `--json` path: cargo runs bench binaries with cwd set to the
/// *package* root (rust/), but the baseline and the CI gate live at the
/// workspace root — so relative paths are anchored there.
fn resolve_json_path(path: &str) -> std::path::PathBuf {
    let p = std::path::Path::new(path);
    if p.is_absolute() {
        p.to_path_buf()
    } else {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(p)
    }
}

/// Machine-readable record (the CI perf gate's schema).
#[derive(Default)]
struct PerfRecord {
    quick: bool,
    handler_decide_ns_10k: f64,
    spf_solve_ms_1k: f64,
    spf_solve_ms_10k: f64,
    fluid_gain_ns: f64,
    cache_score_ns: f64,
    resilience_decide_ns: f64,
    predict_update_ns: f64,
    timer_wheel_ns: f64,
    sim_requests_per_sec: f64,
    events_per_sec: f64,
}

impl PerfRecord {
    fn to_json(&self) -> String {
        format!(
            "{{\n  \"schema\": 1,\n  \"provisional\": false,\n  \"quick\": {},\n  \
             \"handler_decide_ns_10k\": {:.1},\n  \"spf_solve_ms_1k\": {:.3},\n  \
             \"spf_solve_ms_10k\": {:.3},\n  \"fluid_gain_ns\": {:.1},\n  \
             \"cache_score_ns\": {:.1},\n  \
             \"resilience_decide_ns\": {:.1},\n  \
             \"predict_update_ns\": {:.1},\n  \
             \"timer_wheel_ns\": {:.1},\n  \
             \"sim_requests_per_sec\": {:.1},\n  \"events_per_sec\": {:.1}\n}}\n",
            self.quick,
            self.handler_decide_ns_10k,
            self.spf_solve_ms_1k,
            self.spf_solve_ms_10k,
            self.fluid_gain_ns,
            self.cache_score_ns,
            self.resilience_decide_ns,
            self.predict_update_ns,
            self.timer_wheel_ns,
            self.sim_requests_per_sec,
            self.events_per_sec,
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut rec = PerfRecord { quick, ..Default::default() };

    println!("## L3 hot-path microbenchmarks{}\n", if quick { " (quick)" } else { "" });

    println!("handler decision latency (paper: <20 ms @10k servers):");
    let handler_sizes: &[usize] = if quick { &[100, 10_000] } else { &[10, 100, 1000, 10_000] };
    for &n in handler_sizes {
        let ms = bench_handler(n);
        println!("  {n:>6} servers: {ms:>10.4} ms/decision");
        if n == 10_000 {
            rec.handler_decide_ns_10k = ms * 1e6;
        }
    }

    println!("\nplacement solve (Fig 17c target <200 ms @10k servers):");
    let table = zoo::paper_zoo();
    // quick mode shortens the trace, not the server counts — the gated
    // numbers stay at the same scale points
    let place_duration_ms = if quick { 2_000.0 } else { 10_000.0 };
    for n in [100usize, 1000, 10_000] {
        if quick && n == 100 {
            continue;
        }
        let cloud = EdgeCloud::large_scale(n);
        let spec = WorkloadSpec {
            rps: 20.0 * n as f64,
            streams: (4 * n).min(40_000),
            duration_ms: place_duration_ms,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let services: Vec<ServiceId> = {
            let mut s: Vec<_> = reqs.iter().map(|r| r.service).collect();
            s.sort();
            s.dedup();
            s
        };
        let allocator = Allocator::new(&table, GpuSpec::P100);
        let allocs: HashMap<ServiceId, _> = services
            .iter()
            .map(|&id| (id, allocator.allocate(id, Overrides::default())))
            .collect();
        let t0 = Instant::now();
        let mut eval =
            FluidEval::from_requests(&table, &allocs, &cloud, &reqs, place_duration_ms);
        let build_ms = t0.elapsed().as_secs_f64() * 1000.0;
        let t0 = Instant::now();
        let placement = sssp(&[], &services, n, &mut eval);
        let solve_ms = t0.elapsed().as_secs_f64() * 1000.0;
        println!("  {n:>6} servers: build {build_ms:>8.1} ms, solve \
                  {solve_ms:>8.1} ms, {} items", placement.len());
        match n {
            1000 => rec.spf_solve_ms_1k = solve_ms,
            10_000 => rec.spf_solve_ms_10k = solve_ms,
            _ => {}
        }

        // fluid gain query cost
        let item = PlacementItem { service: services[0], server: ServerId(0) };
        let t0 = Instant::now();
        let reps = 100_000;
        let mut acc = 0.0;
        for _ in 0..reps {
            acc += eval.gain(item);
        }
        let ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
        println!("          gain query: {ns:.0} ns (acc {acc:.1})");
        if n == 10_000 {
            rec.fluid_gain_ns = ns;
        }
    }

    println!("\nweight-cache scoring (admit + warm_frac, DESIGN.md §Model cache):");
    // The per-spawn / per-gain cache hot path: half the ops mutate LRU
    // state (admit), half are the read-only residency probe placement
    // scoring issues (warm_frac).  Deterministic stream — timestamps are
    // the loop counter, services rotate through the whole zoo.
    let zoo_ids: Vec<ServiceId> = table.services().map(|s| s.id).collect();
    let mut fabric = epara::modelcache::CacheFabric::new(&table, 64, 24_000.0);
    let cache_reps = if quick { 200_000 } else { 1_000_000 };
    let mut acc = 0.0;
    let t0 = Instant::now();
    for i in 0..cache_reps {
        let server = ServerId((i % 64) as u32);
        let svc = zoo_ids[i % zoo_ids.len()];
        if i % 2 == 0 {
            acc += fabric.admit(server, svc, i as f64).bytes_loaded_mb;
        } else {
            acc += fabric.warm_frac(server, svc);
        }
    }
    let cache_ns = t0.elapsed().as_secs_f64() * 1e9 / cache_reps as f64;
    println!("  admit/warm_frac mix: {cache_ns:.0} ns/op (acc {acc:.1})");
    rec.cache_score_ns = cache_ns;

    println!("\nresilience decision (breaker + retry budget, DESIGN.md §Resilience):");
    // The per-request resilience hot path: one breaker admit, one outcome
    // record, and a budget accrue/spend pair.  The outcome stream cycles
    // through a failure burst every 64 ops so the breaker actually walks
    // Closed → Open → HalfOpen instead of measuring the Closed fast path
    // alone.  Deterministic: time is the loop counter.
    let rcfg = ResilienceConfig { enabled: true, ..Default::default() };
    let mut breaker = Breaker::new(&rcfg);
    let mut budget = RetryBudget::new(rcfg.retry_budget, rcfg.retry_burst);
    let resil_reps = if quick { 200_000 } else { 1_000_000 };
    let mut acc = 0u64;
    let t0 = Instant::now();
    for i in 0..resil_reps {
        let now = i as f64;
        budget.on_offered();
        match breaker.admit(now) {
            Admit::ShortCircuit { .. } => {
                acc += 1;
            }
            _ => {
                let ok = i % 64 < 48;
                if breaker.record(now, ok) {
                    acc += 1;
                }
                if !ok && budget.try_take() {
                    acc += 1;
                }
            }
        }
    }
    let resil_ns = t0.elapsed().as_secs_f64() * 1e9 / resil_reps as f64;
    println!("  admit/record/budget mix: {resil_ns:.0} ns/op (acc {acc})");
    rec.resilience_decide_ns = resil_ns;

    println!("\npredict model update (DESIGN.md §Prediction):");
    // The per-request prediction hot path: one latency-model observe +
    // predict pair plus one forecaster arrival fold.  The sample stream
    // cycles a few latency regimes so the EWMA/quantile updates take
    // their real branches; virtual time is the loop counter, so bucket
    // closes (and the Holt update) happen at the configured cadence.
    let pcfg = PredictConfig { enabled: true, ..Default::default() };
    let mut lm = LatencyModel::new(&pcfg);
    let mut rf = RateForecaster::new(&pcfg);
    let pred_reps = if quick { 200_000 } else { 1_000_000 };
    let mut acc = 0.0;
    let t0 = Instant::now();
    for i in 0..pred_reps {
        lm.observe(5.0 + (i % 7) as f64);
        rf.observe(i as f64);
        if let Some(p) = lm.predict() {
            acc += p;
        }
    }
    let predict_ns = t0.elapsed().as_secs_f64() * 1e9 / pred_reps as f64;
    println!("  observe/forecast mix: {predict_ns:.0} ns/op (acc {acc:.1})");
    rec.predict_update_ns = predict_ns;

    println!("\ntimer wheel maintenance (DESIGN.md §Reactor timers):");
    // The reactor's steady-state deadline pattern: 4k connections arm
    // staggered deadlines spread over 600 ticks (~30 s of 50 ms ticks),
    // each fire immediately re-arms 600 ticks out.  Per-op cost covers
    // the amortized tick walk, cascades across levels, the fire, and the
    // re-insert — the O(live-conns)-per-tick slab scan this replaced
    // would scale with connections instead.
    let mut wheel = TimerWheel::new(0);
    let wheel_conns = 4_096u64;
    for t in 0..wheel_conns {
        wheel.insert(t, 1 + (t % 600));
    }
    let wheel_reps: u64 = if quick { 200_000 } else { 1_000_000 };
    let mut wheel_fired = 0u64;
    let mut rearm: Vec<(u64, u64)> = Vec::new();
    let mut tick = 0u64;
    let t0 = Instant::now();
    while wheel_fired < wheel_reps {
        tick += 1;
        rearm.clear();
        wheel.advance(tick, |token, expires| rearm.push((token, expires)));
        for &(token, expires) in &rearm {
            wheel_fired += 1;
            wheel.insert(token, expires + 600);
        }
    }
    let wheel_ns = t0.elapsed().as_secs_f64() * 1e9 / wheel_fired as f64;
    println!(
        "  fire+re-arm over {tick} ticks: {wheel_ns:.0} ns/op \
         ({wheel_fired} fires, {} moves)",
        wheel.work()
    );
    rec.timer_wheel_ns = wheel_ns;

    println!("\nsimulator event throughput:");
    let cloud = EdgeCloud::testbed();
    let sim_duration_ms = if quick { 15_000.0 } else { 30_000.0 };
    let spec = WorkloadSpec {
        mix: Mix::Production(0),
        rps: 400.0,
        duration_ms: sim_duration_ms,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    let n_reqs = reqs.len();
    let cfg = SimConfig {
        policy: PolicyConfig::epara(),
        duration_ms: sim_duration_ms,
        ..Default::default()
    };
    let t0 = Instant::now();
    let m = simulate(&table, cloud, reqs, cfg);
    let wall = t0.elapsed().as_secs_f64();
    // every request generates >= 2 events (arrive + finish) + hops
    let events = (m.offered * 2) as f64 * (1.0 + m.mean_offloads());
    rec.sim_requests_per_sec = n_reqs as f64 / wall;
    rec.events_per_sec = events / wall;
    println!("  {n_reqs} requests / {wall:.3} s wall = {:.0} req/s, \
              ~{:.0} events/s",
             rec.sim_requests_per_sec, rec.events_per_sec);

    if let Some(path) = json_path {
        let out = resolve_json_path(&path);
        std::fs::write(&out, rec.to_json()).expect("write bench JSON");
        println!("\nwrote {}", out.display());
    }
}
