//! Fig. 20 — case study 2: segmentation in EPARA (§5.3.4, Table 2).
//!
//! Per-service goodput on four P100 servers for the segmentation roster,
//! EPARA vs Galaxy (the MP-centric edge baseline), plus real UNet-mini
//! latency through PJRT when artifacts are present.
//!
//! Regenerate with:  cargo bench --bench fig20_seg_case

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec, Link};
use epara::core::ServiceId;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() {
    let table = zoo::paper_zoo();
    let alloc = Allocator::new(&table, GpuSpec::P100);
    let services = zoo::segmentation_case_study_services();

    println!("## Fig 20 — adaptive deployment for segmentation (§5.3.4)");
    println!("{:>18} {:>6} {:>4} {:>9} {:>4} {:>4}",
             "service", "BS", "MT", "MP", "MF", "DP");
    for &s in &services {
        let a = alloc.allocate(s, Overrides::default());
        println!("{:>18} {:>6} {:>4} {:>9} {:>4} {:>4}",
                 table.spec(s).name, a.ops.bs, a.ops.mt,
                 format!("{:?}", a.ops.mp), a.ops.mf, a.ops.dp);
    }
    println!("(paper: UNet BS8 | Deeplab BS4 | SCTNet BS4 | MaskFormer \
              TP2+BS8 | OMG-Seg TP2+BS4; video: MF4 / MF4+DP2)\n");

    println!("## Fig 20 — per-service goodput on 4 P100 servers");
    let cloud = EdgeCloud::uniform(4, 1, GpuSpec::P100, Link::SWITCH_10G);
    let spec = WorkloadSpec {
        mix: Mix::Mixed,
        services: services.clone(),
        rps: 50.0,
        duration_ms: 20_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    for policy in [PolicyConfig::epara(), PolicyConfig::galaxy()] {
        let cfg = SimConfig { policy, duration_ms: 20_000.0, ..Default::default() };
        let m = simulate(&table, cloud.clone(), reqs.clone(), cfg);
        println!("{}: total satisfied {:.1}/{}", policy.name, m.satisfied,
                 m.offered);
        let mut rows: Vec<(ServiceId, f64)> =
            m.per_service.iter().map(|(k, v)| (*k, *v)).collect();
        rows.sort_by_key(|(k, _)| *k);
        for (svc, sat) in rows {
            let offered = reqs.iter().filter(|r| r.service == svc).count();
            println!("    {:>18} {:>8.1}/{offered}", table.spec(svc).name, sat);
        }
    }

    let dir = epara::artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("\n## real UNet-mini latency (PJRT CPU)");
        let engine = epara::runtime::Engine::load(&dir).expect("engine");
        for bs in [1usize, 2, 4] {
            let shape = [bs, 64, 64, 3];
            let img = vec![0.3f32; shape.iter().product()];
            let _ = engine.segment(bs, &img, &shape); // warm-up compile
            let t0 = std::time::Instant::now();
            let reps = 5;
            for _ in 0..reps {
                let _ = engine.segment(bs, &img, &shape).expect("segment");
            }
            let ms = t0.elapsed().as_secs_f64() * 1000.0 / reps as f64;
            println!("  bs{bs}: {ms:.1} ms/batch ({:.1} frames/s)",
                     bs as f64 * 1000.0 / ms);
        }
    }
}
