//! Fig. 8 — case study: LLMs in EPARA (§4.3).
//!
//! Per-category GPU efficiency and SLO attainment of the four LLM service
//! classes on four P100 servers, EPARA vs the non-parallel deployment,
//! plus real token rates from the artifact-backed tiny LLM when present.
//!
//! Regenerate with:  cargo bench --bench fig08_llm_case

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec, Link};
use epara::profile::zoo::{self, ids};
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() {
    let table = zoo::paper_zoo();
    let alloc = Allocator::new(&table, GpuSpec::P100);

    println!("## Fig 8 — §4.3 LLM configurations and token rates");
    println!("{:>20} {:>6} {:>4} {:>9} {:>4} {:>4} {:>12}",
             "service", "BS", "MT", "MP", "MF", "DP", "tokens/s");
    let all_svcs = zoo::llm_case_study_services();
    for &s in &all_svcs {
        let a = alloc.allocate(s, Overrides::default());
        let toks = table.throughput(s, a.ops.bs, a.ops.mp, a.ops.mt)
            * a.ops.dp as f64;
        println!("{:>20} {:>6} {:>4} {:>9} {:>4} {:>4} {:>12.1}",
                 table.spec(s).name, a.ops.bs, a.ops.mt,
                 format!("{:?}", a.ops.mp), a.ops.mf, a.ops.dp, toks);
    }
    println!("(paper anchors: Qwen1.5B 87 tok/s BS2; Llama8B 24; DS16B 46; \
              Qwen32B 24)\n");

    println!("## Fig 8 — serving the four-category LLM mix on 4 P100 servers");
    // the four Fig. 5 categories, co-residable on 4 GPUs (§4.3: Qwen-32B
    // alone needs all four GPUs, so the served mix uses the <=2-GPU pair)
    let svcs = vec![
        ids::QWEN_1_5B,
        epara::core::ServiceId(ids::QWEN_1_5B.0 + ids::HCI_OFFSET),
        ids::LLAMA3_8B,
        epara::core::ServiceId(ids::LLAMA3_8B.0 + ids::HCI_OFFSET),
    ];
    let cloud = EdgeCloud::uniform(4, 1, GpuSpec::P100, Link::SWITCH_10G);
    // 4 P100s serve ~3 LLM req/s total (a 64-token request occupies a
    // slice for ~1.5–4 s) — the paper's Fig. 8 workload is similarly light
    let spec = WorkloadSpec {
        mix: Mix::Mixed,
        services: svcs.clone(),
        rps: 3.0,
        duration_ms: 20_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    for policy in [PolicyConfig::epara(), PolicyConfig::alpaserve(),
                   PolicyConfig::detransformer()] {
        let cfg = SimConfig { policy, duration_ms: 20_000.0, ..Default::default() };
        let mut m = simulate(&table, cloud.clone(), reqs.clone(), cfg);
        println!("  {}", m.report(policy.name));
    }

    // real tiny-LLM token rate (single GPU vs TP2 vs PP2)
    let dir = epara::artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("\n## real tiny_llm token rates (PJRT CPU, bs2, 8 tokens)");
        let engine = epara::runtime::Engine::load(&dir).expect("engine");
        let prompts: Vec<Vec<i32>> = (0..2)
            .map(|b| (0..32).map(|i| ((b + i * 3) % 512) as i32).collect())
            .collect();
        type GenFn<'a> = Box<dyn Fn() -> anyhow::Result<Vec<Vec<i32>>> + 'a>;
        for (label, f) in [
            ("full", Box::new(|| engine.llm_generate(2, &prompts, 8)) as GenFn<'_>),
            ("tp2", Box::new(|| engine.llm_generate_tp2(&prompts, 8))),
            ("pp2", Box::new(|| engine.llm_generate_pp2(&prompts, 8))),
        ] {
            let _ = f(); // warm-up compile
            let t0 = std::time::Instant::now();
            let _ = f().expect(label);
            let s = t0.elapsed().as_secs_f64();
            println!("  {label:>5}: {:.1} tokens/s (2 seqs x 8 tokens)",
                     16.0 / s);
        }
    }
}
