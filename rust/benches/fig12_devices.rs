//! Fig. 12 — embedded devices in the real testbed.
//!
//! (a) Bluetooth HC-05 transfer delay vs file size (105 ms @64 B,
//!     1039 ms @1 KB);
//! (b) VGG-style device/server PP offloading at conv2/conv4 — executed
//!     for real through the PJRT runtime when artifacts are present.
//!
//! Regenerate with:  cargo bench --bench fig12_devices

use epara::cluster::Link;

fn main() {
    println!("## Fig 12a — Bluetooth transfer delay (HC-05 + Basys3)");
    println!("{:>10} {:>12}", "size", "delay (ms)");
    for bytes in [64.0f64, 128.0, 256.0, 512.0, 1024.0, 2048.0] {
        println!("{:>9}B {:>12.0}", bytes,
                 Link::BLUETOOTH.transfer_ms(bytes / 1024.0));
    }
    println!("(paper anchors: 105 ms @64 B, 1039 ms @1 KB)\n");

    println!("## Fig 12b — classifier offload points (U50-style device PP)");
    let dir = epara::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built — run `make artifacts`)");
        return;
    }
    let engine = epara::runtime::Engine::load(&dir).expect("engine");
    let shape = [1usize, 32, 32, 3];
    let image: Vec<f32> = (0..shape.iter().product::<usize>())
        .map(|i| ((i * 29) % 253) as f32 / 253.0)
        .collect();
    let t0 = std::time::Instant::now();
    let full = engine.classify(1, &image, &shape).expect("classify");
    let full_ms = t0.elapsed().as_secs_f64() * 1000.0;
    println!("{:>8} {:>12} {:>12} {:>16} {:>8}",
             "split", "dev+srv ms", "act bytes", "act link @100M", "correct");
    for split in ["conv2", "conv4"] {
        let t0 = std::time::Instant::now();
        let (logits, act_bytes) =
            engine.classify_split(split, &image, &shape).expect(split);
        let ms = t0.elapsed().as_secs_f64() * 1000.0;
        let ok = epara::runtime::max_abs_diff(&logits, &full) < 1e-4;
        println!("{split:>8} {ms:>12.2} {act_bytes:>12} {:>14.2}ms {:>8}",
                 Link::EDGE_100M.transfer_ms(act_bytes as f64 / 1024.0),
                 if ok { "yes" } else { "NO" });
    }
    println!("single-GPU reference: {full_ms:.2} ms");
}
