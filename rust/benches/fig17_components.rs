//! Fig. 17 — effectiveness and overhead of EPARA's design components.
//!
//! (a) request handling effect (paper: 2.2–2.4× for ≤1 GPU, 2.9–3.1× for
//!     >1 GPU tasks);
//! (b) placement vs LRU/LFU/MFU (paper: up to 1.9×);
//! (c) placement scheduling latency vs server count (<200 ms @10k);
//! (d) information-sync delay vs (bandwidth, servers) (≤10 s at the
//!     paper's two anchor points);
//! (e) offloading count vs sync overhead (<1 below 100 ms, rising).
//!
//! Regenerate with:  cargo bench --bench fig17_components

use std::collections::HashMap;

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec};
use epara::core::ServiceId;
use epara::placement::cache_baselines::CachePolicy;
use epara::placement::{sssp, FluidEval};
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::sync::SyncConfig;
use epara::workload::{generate, Mix, WorkloadSpec};

fn goodput(policy: PolicyConfig, mix: Mix, rps: f64, sync_interval: f64) -> f64 {
    let table = zoo::paper_zoo();
    let spec = WorkloadSpec { mix, rps, duration_ms: 15_000.0, ..Default::default() };
    let reqs = generate(&spec, &table, &EdgeCloud::testbed());
    let cfg = SimConfig {
        policy,
        duration_ms: 15_000.0,
        sync: SyncConfig { interval_ms: sync_interval, ..Default::default() },
        ..Default::default()
    };
    simulate(&table, EdgeCloud::testbed(), reqs, cfg).satisfied
}

fn main() {
    println!("## Fig 17a — effect of request handling (offloading)");
    println!("{:>12} {:>12} {:>12} {:>7}", "workload", "EPARA", "no-offload", "gain");
    for (label, mix) in [("W0 (<=1GPU)", Mix::Production(0)),
                         ("W4 (>1GPU)", Mix::Production(4))] {
        let with = goodput(PolicyConfig::epara(), mix, 250.0, 1000.0);
        let without = goodput(PolicyConfig::epara_no_offload(), mix, 250.0, 1000.0);
        println!("{label:>12} {with:>12.1} {without:>12.1} {:>6.1}x",
                 with / without.max(1e-9));
    }
    println!("(paper: 2.2-2.4x <=1 GPU, 2.9-3.1x >1 GPU)\n");

    println!("## Fig 17b — placement strategy vs cache policies");
    println!("{:>12} {:>12} {:>7}", "strategy", "goodput", "vs EPARA");
    let epara = goodput(PolicyConfig::epara(), Mix::Production(2), 200.0, 1000.0);
    println!("{:>12} {epara:>12.1} {:>7}", "EPARA", "1.00");
    for policy in [CachePolicy::Lru, CachePolicy::Lfu, CachePolicy::Mfu] {
        let v = goodput(PolicyConfig::epara_cache_placement(policy),
                        Mix::Production(2), 200.0, 1000.0);
        println!("{:>12} {v:>12.1} {:>7.2}", format!("{policy:?}"),
                 epara / v.max(1e-9));
    }
    println!("(paper: up to 1.9x)\n");

    println!("## Fig 17c — placement scheduling latency vs servers");
    println!("{:>9} {:>12} {:>12}", "servers", "solve (ms)", "items");
    let table = zoo::paper_zoo();
    for n in [100usize, 1000, 10_000] {
        let cloud = EdgeCloud::large_scale(n);
        let spec = WorkloadSpec {
            rps: 20.0 * n as f64,
            streams: (4 * n).min(40_000),
            duration_ms: 10_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &cloud);
        let services: Vec<ServiceId> = {
            let mut s: Vec<_> = reqs.iter().map(|r| r.service).collect();
            s.sort();
            s.dedup();
            s
        };
        let allocator = Allocator::new(&table, GpuSpec::P100);
        let allocs: HashMap<ServiceId, _> = services
            .iter()
            .map(|&id| (id, allocator.allocate(id, Overrides::default())))
            .collect();
        let t0 = std::time::Instant::now();
        let mut eval = FluidEval::from_requests(&table, &allocs, &cloud,
                                                &reqs, 10_000.0);
        let placement = sssp(&[], &services, n, &mut eval);
        let ms = t0.elapsed().as_secs_f64() * 1000.0;
        println!("{n:>9} {ms:>12.1} {:>12}", placement.len());
    }
    println!("(paper: < 200 ms below 10k servers)\n");

    println!("## Fig 17d — information sync delay");
    println!("{:>12} {:>9} {:>12}", "bandwidth", "servers", "delay (ms)");
    for (bw, n) in [(50.0, 100usize), (100.0, 300), (500.0, 1000),
                    (500.0, 10_000)] {
        let cfg = SyncConfig { bandwidth_mbps: bw, ..Default::default() };
        println!("{:>10}Mb {n:>9} {:>12.1}", bw, cfg.full_sync_delay_ms(n));
    }
    println!("(paper: within 10 s at (50 Mbps,100) and (500 Mbps,1000))\n");

    println!("## Fig 17e — offload count vs sync overhead");
    println!("{:>14} {:>14}", "interval (ms)", "avg offloads");
    for interval in [50.0, 100.0, 500.0, 2000.0, 5000.0] {
        let table = zoo::paper_zoo();
        let spec = WorkloadSpec {
            mix: Mix::Production(0),
            rps: 250.0,
            duration_ms: 15_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &EdgeCloud::testbed());
        let cfg = SimConfig {
            policy: PolicyConfig::epara(),
            duration_ms: 15_000.0,
            sync: SyncConfig { interval_ms: interval, ..Default::default() },
            ..Default::default()
        };
        let mut m = simulate(&table, EdgeCloud::testbed(), reqs, cfg);
        println!("{interval:>14.0} {:>14.3}", m.mean_offloads());
        let _ = m.report("");
    }
    println!("(paper: < 1 when sync overhead < 100 ms, rising after)");
}
