//! Fig. 10 — testbed goodput (reqs/sec) across five production workloads
//! and five schemes.  Paper headline: EPARA up to 2.1× / 2.2× / 2.5× /
//! 3.2× over InterEdge / AlpaServe / Galaxy / SERV-P on mixed traffic.
//!
//! Regenerate with:  cargo bench --bench fig10_testbed_goodput

use epara::cluster::EdgeCloud;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() {
    let table = zoo::paper_zoo();
    let policies = PolicyConfig::testbed_baselines();
    let rps = 250.0; // saturating load on the 4-P100 testbed

    println!("## Fig 10 — goodput (req/s) on the 6-server/4-P100 testbed, \
              load {rps} req/s");
    print!("{:>10}", "workload");
    for p in &policies {
        print!(" {:>12}", p.name);
    }
    println!(" {:>10}", "best gain");

    let mut avg = vec![0.0f64; policies.len()];
    for w in 0..5u8 {
        let spec = WorkloadSpec {
            mix: Mix::Production(w),
            rps,
            duration_ms: 20_000.0,
            seed: 100 + w as u64,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &EdgeCloud::testbed());
        print!("{:>10}", format!("W{w}"));
        let mut row = Vec::new();
        for p in &policies {
            let cfg = SimConfig { policy: *p, duration_ms: 20_000.0, ..Default::default() };
            let m = simulate(&table, EdgeCloud::testbed(), reqs.clone(), cfg);
            row.push(m.goodput_rps());
            print!(" {:>12.1}", m.goodput_rps());
        }
        for (a, v) in avg.iter_mut().zip(&row) {
            *a += v / 5.0;
        }
        let worst_base = row[1..].iter().cloned().fold(f64::INFINITY, f64::min);
        println!(" {:>9.1}x", row[0] / worst_base.max(1e-9));
    }

    print!("{:>10}", "avg");
    for v in &avg {
        print!(" {:>12.1}", v);
    }
    println!();
    for (i, p) in policies.iter().enumerate().skip(1) {
        println!("EPARA / {:<12} = {:.2}x  (paper: up to {})",
                 p.name, avg[0] / avg[i].max(1e-9),
                 ["", "2.1x", "2.2x", "2.5x", "3.2x"][i]);
    }
}
