//! Fig. 13 — resource monitor at maximum goodput: compute occupancy and
//! VRAM utilization (paper: EPARA 95%+ compute, 98%+ VRAM, leading
//! AlpaServe and far ahead of MT-less Galaxy).
//!
//! Regenerate with:  cargo bench --bench fig13_resources

use epara::cluster::EdgeCloud;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() {
    let table = zoo::paper_zoo();
    println!("## Fig 13 — utilization while serving mixed workloads at max \
              goodput");
    println!("{:>14} {:>12} {:>12} {:>12}",
             "scheme", "goodput", "compute %", "VRAM %");
    for policy in [PolicyConfig::epara(), PolicyConfig::alpaserve(),
                   PolicyConfig::galaxy()] {
        let spec = WorkloadSpec {
            mix: Mix::Production(4), // heavy roster: VRAM-resident LLMs + MaskFormer
            rps: 400.0, // saturating
            duration_ms: 20_000.0,
            ..Default::default()
        };
        let reqs = generate(&spec, &table, &EdgeCloud::testbed());
        let cfg = SimConfig { policy, duration_ms: 20_000.0, ..Default::default() };
        let m = simulate(&table, EdgeCloud::testbed(), reqs, cfg);
        println!("{:>14} {:>12.1} {:>12.1} {:>12.1}",
                 policy.name, m.goodput_rps(),
                 m.gpu_utilization() * 100.0, m.vram_utilization() * 100.0);
    }
    println!("(paper: EPARA 95%+ compute / 98%+ VRAM)");
}
