//! Quickstart: the full three-layer stack in one minute.
//!
//!   1. load the AOT artifacts (JAX+Pallas → HLO text, built once by
//!      `make artifacts`) into the PJRT CPU engine;
//!   2. verify one golden fixture (python oracle == rust execution);
//!   3. serve a small mixed workload (LLM chat + segmentation +
//!      classification) through the live coordinator with BS batching
//!      and DP round-robin;
//!   4. print throughput and latency percentiles.
//!
//! Run with:  cargo run --release --example quickstart

use epara::coordinator::{synthetic_workload, BatchConfig, Coordinator};
use epara::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = epara::artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no artifacts at {dir:?} — run `make artifacts` first"
    );

    // --- 1+2: engine + one golden check ---------------------------------
    println!("== loading engine from {dir:?}");
    let engine = Engine::load(&dir)?;
    let diff = engine.verify_golden("llm.decode.bs2")?;
    println!("golden llm.decode.bs2: max |diff| = {diff:.2e} (vs python oracle)");
    engine.verify_generate_golden()?;
    println!("golden llm.generate.bs2: rust greedy tokens == python, exact");

    // one real generation, end to end
    let prompt: Vec<i32> = (0..32).map(|i| (i * 11 % 512) as i32).collect();
    let tokens = engine.llm_generate(1, &[prompt], 8)?;
    println!("tiny_llm generated tokens: {:?}", tokens[0]);
    drop(engine); // the coordinator spawns its own engine thread

    // --- 3: live serving --------------------------------------------------
    println!("\n== serving 30 mixed requests (real PJRT inference)");
    let coord = Coordinator::new(dir, BatchConfig::default())?;
    let workload = synthetic_workload(30, 100.0, 7);
    let mut stats = coord.serve(workload)?;

    // --- 4: report ---------------------------------------------------------
    println!("{}", stats.report("quickstart"));
    anyhow::ensure!(stats.errors == 0, "serving errors");
    println!("\nquickstart OK — all three layers compose.");
    Ok(())
}
