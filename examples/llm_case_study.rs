//! Case study 1 (§4.3, Fig. 8): LLMs from chats to robots.
//!
//! Reproduces the paper's workflow end to end:
//!  1. categorize the four LLM service classes (Fig. 5 axes);
//!  2. run the §4.1 adaptive deployment (MP → BS → MT → MF/DP) and print
//!     the chosen operators next to the paper's configurations;
//!  3. simulate the four-server P100 testbed serving the LLM workload and
//!     report per-category goodput/SLO attainment (the Fig. 8 bars);
//!  4. demonstrate the real thing on the artifact-backed tiny LLM:
//!     single-GPU, TP2 (rust-side combine), and PP2 (rust-side pipe)
//!     generations must agree token-for-token.
//!
//! Run with:  cargo run --release --example llm_case_study

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec, Link};
use epara::profile::zoo::{self, ids};
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let table = zoo::paper_zoo();
    let alloc = Allocator::new(&table, GpuSpec::P100);

    println!("== §4.3 adaptive deployment for the LLM case study\n");
    println!("{:<22} {:<16} {:>4} {:>4} {:>9} {:>4} {:>4}  paper (§4.3)",
             "service", "category", "BS", "MT", "MP", "MF", "DP");
    let paper = [
        (ids::QWEN_1_5B, "BS2, MT2"),
        (ids::LLAMA3_8B, "BS4+TP2"),
        (ids::DEEPSEEK_16B, "BS4+TP2"),
        (ids::QWEN_32B, "BS4+TP2+PP2"),
    ];
    let mut services = Vec::new();
    for (id, paper_cfg) in paper {
        for off in [0, ids::HCI_OFFSET] {
            let sid = epara::core::ServiceId(id.0 + off);
            if table.get_spec(sid).is_none() {
                continue;
            }
            let a = alloc.allocate(sid, Overrides::default());
            println!(
                "{:<22} {:<16} {:>4} {:>4} {:>9} {:>4} {:>4}  {}",
                table.spec(sid).name,
                format!("{:?}", a.category),
                a.ops.bs, a.ops.mt, format!("{:?}", a.ops.mp),
                a.ops.mf, a.ops.dp,
                if off == 0 { paper_cfg } else { "(HCI: +MF/DP)" },
            );
            services.push(sid);
        }
    }

    println!("\n== Fig. 8: four P100 servers serving the LLM mix");
    let cloud = EdgeCloud::uniform(4, 1, GpuSpec::P100, Link::SWITCH_10G);
    let spec = WorkloadSpec {
        mix: Mix::Mixed,
        services: services.clone(),
        rps: 12.0,
        duration_ms: 30_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    println!("workload: {} requests over 30 s", reqs.len());
    for policy in [PolicyConfig::epara(), PolicyConfig::alpaserve()] {
        let cfg = SimConfig { policy, duration_ms: 30_000.0, ..Default::default() };
        let mut m = simulate(&table, cloud.clone(), reqs.clone(), cfg);
        println!("  {}", m.report(policy.name));
    }

    // --- the real thing on the tiny LLM ---------------------------------
    let dir = epara::artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("\n== real PJRT generation: full model vs TP2 vs PP2");
        let engine = epara::runtime::Engine::load(&dir)?;
        let prompts: Vec<Vec<i32>> = (0..2)
            .map(|b| (0..32).map(|i| ((b * 97 + i * 13) % 512) as i32).collect())
            .collect();
        let t0 = std::time::Instant::now();
        let full = engine.llm_generate(2, &prompts, 6)?;
        let t_full = t0.elapsed().as_secs_f64() * 1000.0;
        let t0 = std::time::Instant::now();
        let tp2 = engine.llm_generate_tp2(&prompts, 6)?;
        let t_tp2 = t0.elapsed().as_secs_f64() * 1000.0;
        let t0 = std::time::Instant::now();
        let pp2 = engine.llm_generate_pp2(&prompts, 6)?;
        let t_pp2 = t0.elapsed().as_secs_f64() * 1000.0;
        println!("  full model : {:?}  ({t_full:.0} ms)", full[0]);
        println!("  TP2 combine: {:?}  ({t_tp2:.0} ms)", tp2[0]);
        println!("  PP2 pipe   : {:?}  ({t_pp2:.0} ms)", pp2[0]);
        anyhow::ensure!(full == tp2 && full == pp2,
                        "MP compositions diverged from the full model!");
        println!("  all three agree token-for-token ✓");
    } else {
        println!("\n(skip real generation: run `make artifacts` first)");
    }
    Ok(())
}
