//! Large-scale co-simulation (§5.2, Fig. 14/15): goodput across server
//! counts and the GPU count needed to fully serve a fixed load.
//!
//! Run with:  cargo run --release --example large_scale_sim
//! Optional env: EPARA_MAX_SERVERS (default 32).

use epara::cluster::EdgeCloud;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let table = zoo::paper_zoo();
    let max_servers: usize = std::env::var("EPARA_MAX_SERVERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);

    println!("== Fig. 14: goodput vs cluster size (8×P100 per server)\n");
    println!("{:>8} {:>12} {:>12} {:>12} {:>12}",
             "servers", "EPARA", "InterEdge", "AlpaServe", "SERV-P");
    let mut n = 4;
    while n <= max_servers {
        let mut row = format!("{n:>8}");
        for policy in [
            PolicyConfig::epara(),
            PolicyConfig::interedge(),
            PolicyConfig::alpaserve(),
            PolicyConfig::servp(),
        ] {
            let cloud = EdgeCloud::large_scale(n);
            let spec = WorkloadSpec {
                mix: Mix::Mixed,
                rps: 60.0 * n as f64,
                streams: 40 * n,
                duration_ms: 15_000.0,
                ..Default::default()
            };
            let reqs = generate(&spec, &table, &cloud);
            let cfg = SimConfig {
                policy,
                duration_ms: 15_000.0,
                ..Default::default()
            };
            let m = simulate(&table, cloud, reqs, cfg);
            row += &format!(" {:>12.1}", m.goodput_rps());
        }
        println!("{row}");
        n *= 2;
    }

    println!("\n== Fig. 15: GPUs needed to satisfy a fixed load within SLO\n");
    let target_ratio = 0.95;
    println!("{:>14} {:>10}", "policy", "GPUs");
    for policy in [PolicyConfig::epara(), PolicyConfig::interedge(),
                   PolicyConfig::alpaserve()] {
        let mut gpus_needed = None;
        for gpus in [1usize, 2, 3, 4, 6, 8, 12, 16, 24, 32] {
            let cloud = EdgeCloud::uniform(
                8, gpus, epara::cluster::GpuSpec::P100,
                epara::cluster::Link::SWITCH_10G);
            let spec = WorkloadSpec {
                mix: Mix::Production(3),
                rps: 300.0,
                duration_ms: 15_000.0,
                ..Default::default()
            };
            let reqs = generate(&spec, &table, &cloud);
            let cfg = SimConfig { policy, duration_ms: 15_000.0, ..Default::default() };
            let m = simulate(&table, cloud, reqs, cfg);
            if m.satisfaction_ratio() >= target_ratio {
                gpus_needed = Some(8 * gpus);
                break;
            }
        }
        println!("{:>14} {:>10}", policy.name,
                 gpus_needed.map(|g| g.to_string()).unwrap_or_else(|| "->256+".into()));
    }
    Ok(())
}
