//! Edge devices (§5.1.2, Fig. 12): Bluetooth microcontrollers and
//! accelerator-card offloading.
//!
//!  * Fig. 12a — HC-05 Bluetooth link: transfer delay vs payload size
//!    (paper: 105 ms @64 B, 1039 ms @1 KB);
//!  * Fig. 12b — U50-style device/server pipeline parallelism: the CNN
//!    classifier's conv2/conv4 split executed for real through PJRT, with
//!    activation sizes (what would cross the PCIe/network link);
//!  * device registration: Jetson-class GPUs joining an edge server
//!    (§3.2 "edge device participation") in simulation.
//!
//! Run with:  cargo run --release --example edge_devices

use epara::cluster::{DeviceKind, EdgeCloud, Link};
use epara::core::DeviceId;
use epara::profile::zoo;
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    println!("== Fig. 12a: Bluetooth (HC-05) transfer delay vs payload\n");
    println!("{:>10} {:>12}", "payload", "delay (ms)");
    for bytes in [64.0, 128.0, 256.0, 512.0, 1024.0] {
        let kb = bytes / 1024.0;
        println!("{:>9}B {:>12.0}", bytes, Link::BLUETOOTH.transfer_ms(kb));
    }
    println!("(paper anchors: 105 ms @64 B, 1039 ms @1 KB)");

    // --- Fig. 12b: device/server split through real PJRT -----------------
    let dir = epara::artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("\n== Fig. 12b: classifier offload points (real execution)\n");
        let engine = epara::runtime::Engine::load(&dir)?;
        let shape = [1usize, 32, 32, 3];
        let image: Vec<f32> = (0..shape.iter().product::<usize>())
            .map(|i| ((i * 41) % 255) as f32 / 255.0)
            .collect();
        let full = engine.classify(1, &image, &shape)?;
        println!("{:>8} {:>14} {:>16} {:>10}",
                 "split", "act bytes", "link time @100M", "matches");
        for split in ["conv2", "conv4"] {
            let (logits, act_bytes) = engine.classify_split(split, &image, &shape)?;
            let diff = epara::runtime::max_abs_diff(&logits, &full);
            let link_ms = Link::EDGE_100M.transfer_ms(act_bytes as f64 / 1024.0);
            println!("{:>8} {:>14} {:>15.2}ms {:>10}",
                     split, act_bytes, link_ms,
                     if diff < 1e-4 { "yes" } else { "NO" });
        }
    } else {
        println!("\n(skip Fig. 12b: run `make artifacts` first)");
    }

    // --- device registration in the simulator -----------------------------
    println!("\n== Jetson-class device registration (§3.2)\n");
    let table = zoo::paper_zoo();
    let mut cloud = EdgeCloud::testbed();
    // register four Jetson Nanos at server 4 (one of the GPU-less hosts)
    for i in 0..4 {
        cloud.add_device(DeviceId(100 + i), DeviceKind::JetsonNano,
                         epara::core::ServerId(4));
    }
    let spec = WorkloadSpec {
        mix: Mix::Production(0),
        rps: 120.0,
        duration_ms: 20_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    for (label, allow) in [("with devices", true), ("without devices", false)] {
        let mut policy = PolicyConfig::epara();
        policy.allow_device = allow;
        let cfg = SimConfig { policy, duration_ms: 20_000.0, ..Default::default() };
        let mut m = simulate(&table, cloud.clone(), reqs.clone(), cfg);
        println!("  {}", m.report(label));
    }
    Ok(())
}
