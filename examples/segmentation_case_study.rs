//! Case study 2 (§5.3.4, Fig. 20, Table 2): segmentation in EPARA.
//!
//! The paper picks segmentation because its models span all four task
//! categories: UNet/DeeplabV3+/SCTNet fit one GPU, MaskFormer/OMG-Seg
//! need several; images are latency-sensitive, 60-fps video streams are
//! frequency-sensitive.  We print the Table-2 categorization, run the
//! §4.1 adaptive deployment next to the paper's configs (BS8/BS4/...,
//! TP2+BS8, MF4+DP2), simulate the four-P100 deployment, and run a real
//! UNet-mini segmentation through the PJRT runtime.
//!
//! Run with:  cargo run --release --example segmentation_case_study

use epara::allocator::{Allocator, Overrides};
use epara::cluster::{EdgeCloud, GpuSpec, Link};
use epara::core::{ServiceId, TaskCategory};
use epara::profile::zoo::{self};
use epara::sim::{simulate, PolicyConfig, SimConfig};
use epara::workload::{generate, Mix, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let table = zoo::paper_zoo();
    let alloc = Allocator::new(&table, GpuSpec::P100);
    let services = zoo::segmentation_case_study_services();

    println!("== Table 2: segmentation models by category\n");
    for cat in TaskCategory::ALL {
        let members: Vec<&str> = services
            .iter()
            .filter(|&&s| alloc.categorize(s) == cat)
            .map(|&s| table.spec(s).name.as_str())
            .collect();
        println!("{:<18} {}", format!("{cat:?}"), members.join(", "));
    }

    println!("\n== §4.1 adaptive deployment (paper: UNet BS8 | DeeplabV3+ BS4 \
              | SCTNet BS4 | MaskFormer TP2+BS8 | OMG-Seg TP2+BS4 | video: \
              UNet MF4, Deeplab/SCTNet MF4+DP2)\n");
    println!("{:<18} {:<16} {:>4} {:>4} {:>9} {:>4} {:>4}",
             "service", "category", "BS", "MT", "MP", "MF", "DP");
    for &s in &services {
        let a = alloc.allocate(s, Overrides::default());
        println!("{:<18} {:<16} {:>4} {:>4} {:>9} {:>4} {:>4}",
                 table.spec(s).name, format!("{:?}", a.category),
                 a.ops.bs, a.ops.mt, format!("{:?}", a.ops.mp),
                 a.ops.mf, a.ops.dp);
    }

    println!("\n== Fig. 20: four P100 servers serving the segmentation mix");
    let cloud = EdgeCloud::uniform(4, 1, GpuSpec::P100, Link::SWITCH_10G);
    let spec = WorkloadSpec {
        mix: Mix::Mixed,
        services: services.clone(),
        rps: 40.0,
        duration_ms: 30_000.0,
        ..Default::default()
    };
    let reqs = generate(&spec, &table, &cloud);
    println!("workload: {} requests over 30 s", reqs.len());
    for policy in [PolicyConfig::epara(), PolicyConfig::galaxy()] {
        let cfg = SimConfig { policy, duration_ms: 30_000.0, ..Default::default() };
        let mut m = simulate(&table, cloud.clone(), reqs.clone(), cfg);
        println!("  {}", m.report(policy.name));
        // per-service satisfaction (the Fig. 20 per-model bars)
        let mut per: Vec<(ServiceId, f64)> =
            m.per_service.iter().map(|(k, v)| (*k, *v)).collect();
        per.sort_by_key(|(k, _)| *k);
        for (svc, sat) in per {
            let offered = reqs.iter().filter(|r| r.service == svc).count();
            println!("      {:<18} {:>6.1}/{offered}", table.spec(svc).name, sat);
        }
    }

    // --- real segmentation through PJRT ----------------------------------
    let dir = epara::artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("\n== real UNet-mini segmentation (PJRT, batch 4)");
        let engine = epara::runtime::Engine::load(&dir)?;
        let shape = [4usize, 64, 64, 3];
        let image: Vec<f32> = (0..shape.iter().product::<usize>())
            .map(|i| ((i % 97) as f32) / 97.0)
            .collect();
        let t0 = std::time::Instant::now();
        let out = engine.segment(4, &image, &shape)?;
        let ms = t0.elapsed().as_secs_f64() * 1000.0;
        // argmax per pixel of the first image, count class histogram
        let classes = 8;
        let mut hist = vec![0usize; classes];
        for px in 0..64 * 64 {
            let row = &out[px * classes..(px + 1) * classes];
            let mut best = 0;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            hist[best] += 1;
        }
        println!("  batch of 4 segmented in {ms:.1} ms; class histogram {hist:?}");
    } else {
        println!("\n(skip real segmentation: run `make artifacts` first)");
    }
    Ok(())
}
